"""Fault-tolerant trainer: federated data, checkpoint/restart, elasticity.

The loop composes the substrates:
  * batches from :class:`~repro.data.loader.FederatedDataLoader`
    (prefetch + hedged fetches = straggler mitigation on the data plane);
  * a jitted train step (sharded when a mesh is supplied);
  * periodic checkpoint saves through the write-back cache;
  * **failure handling** — a ``FailureInjector`` can kill any step;
    the trainer restores the newest checkpoint and replays (the loader's
    deterministic step→slice mapping makes replay exact);
  * **elastic rescale** — ``rescale(world)`` re-ranks the loader so the
    same global batch is re-partitioned across a different worker count
    (the batch→device mapping is re-sharded by pjit automatically).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..data.loader import FederatedDataLoader
from ..models import init_lm, lm_loss
from ..sharding.compression import ErrorFeedback
from .checkpoint import FederatedCheckpointer
from .optimizer import AdamWConfig, adamw_update, init_opt_state


class FailureInjector:
    """Deterministic chaos monkey: fail at the listed steps, once each."""

    def __init__(self, fail_at: List[int] = ()) -> None:
        self.fail_at = set(fail_at)
        self.failures = 0

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.failures += 1
            raise RuntimeError(f"injected node failure at step {step}")


@dataclasses.dataclass
class TrainerReport:
    steps_run: int = 0
    restarts: int = 0
    losses: List[float] = dataclasses.field(default_factory=list)
    final_loss: float = float("nan")
    cache_hit_rate: float = 0.0
    restored_from: List[int] = dataclasses.field(default_factory=list)


class Trainer:
    def __init__(self, cfg: ArchConfig, loader: FederatedDataLoader,
                 opt_cfg: Optional[AdamWConfig] = None,
                 checkpointer: Optional[FederatedCheckpointer] = None,
                 checkpoint_every: int = 50,
                 seed: int = 0,
                 aux_weight: float = 0.01,
                 grad_compression: str = "none") -> None:
        self.cfg = cfg
        self.loader = loader
        self.opt_cfg = opt_cfg or AdamWConfig(warmup_steps=10,
                                              total_steps=1000)
        self.checkpointer = checkpointer
        self.checkpoint_every = checkpoint_every
        self.aux_weight = aux_weight
        # int8_ef: blockwise-int8 gradients with error feedback — the
        # codec that compresses the cross-pod all-reduce 4x (DESIGN.md §5)
        self.grad_compression = grad_compression
        key = jax.random.PRNGKey(seed)
        params, _ = init_lm(key, cfg)
        self.state = {"params": params,
                      "opt": init_opt_state(params, self.opt_cfg)}
        if grad_compression == "int8_ef":
            self.state["ef_residual"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        self.step = 0
        self._jit_step = jax.jit(self._train_step)

    # ------------------------------------------------------------------
    def _train_step(self, state, batch):
        def loss_fn(params):
            return lm_loss(params, batch["tokens"], batch["labels"],
                           self.cfg, aux_weight=self.aux_weight)

        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])
        if self.grad_compression == "int8_ef":
            grads, new_res = ErrorFeedback.compress(grads,
                                                    state["ef_residual"])
        new_p, new_opt, metrics = adamw_update(
            grads, state["opt"], state["params"], self.opt_cfg)
        metrics["loss"] = loss
        out = {"params": new_p, "opt": new_opt}
        if self.grad_compression == "int8_ef":
            out["ef_residual"] = new_res
        return out, metrics

    # ------------------------------------------------------------------
    def save(self) -> None:
        if self.checkpointer is not None:
            self.checkpointer.save(self.step, self.state)

    def restore_latest(self) -> bool:
        if self.checkpointer is None:
            return False
        latest = self.checkpointer.latest_step()
        if latest is None:
            return False
        self.state, _ = self.checkpointer.restore(latest, like=self.state)
        self.step = latest
        return True

    def rescale(self, world: int, rank: int = 0) -> None:
        """Elastic re-partition of the data plane."""
        self.loader.world = world
        self.loader.rank = rank
        self.loader._buffer.clear()

    # ------------------------------------------------------------------
    def run(self, num_steps: int,
            failure: Optional[FailureInjector] = None,
            max_restarts: int = 10) -> TrainerReport:
        report = TrainerReport()
        target = self.step + num_steps
        restarts = 0
        if self.checkpointer is not None and self.step == 0:
            self.save()  # step-0 anchor so the first failure can recover
        while self.step < target:
            try:
                if failure is not None:
                    failure.maybe_fail(self.step)
                batch = self.loader.batch(self.step)
                self.state, metrics = self._jit_step(self.state, batch)
                self.step += 1
                report.steps_run += 1
                loss = float(metrics["loss"])
                report.losses.append(loss)
                if self.checkpointer is not None and \
                        self.step % self.checkpoint_every == 0:
                    self.save()
            except RuntimeError as e:
                if "injected" not in str(e) or restarts >= max_restarts:
                    raise
                restarts += 1
                report.restarts += 1
                restored = self.restore_latest()
                if restored:
                    report.restored_from.append(self.step)
                # else: cold restart from current in-memory state
        report.final_loss = report.losses[-1] if report.losses else \
            float("nan")
        report.cache_hit_rate = self.loader.stats.hit_rate
        return report
