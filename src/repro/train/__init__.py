"""Training substrate: optimizer, steps, trainer, checkpointing."""
from .checkpoint import FederatedCheckpointer
from .optimizer import AdamWConfig, adamw_update, init_opt_state
from .trainer import FailureInjector, Trainer, TrainerReport

__all__ = ["FederatedCheckpointer", "AdamWConfig", "adamw_update",
           "init_opt_state", "FailureInjector", "Trainer", "TrainerReport"]
