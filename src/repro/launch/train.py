"""Training launcher: federation-fed, fault-tolerant, arch-selectable.

On real hardware this drives the production mesh; in this container it
runs the reduced config of the selected architecture end-to-end on CPU
(the full configs are exercised by ``dryrun.py``).

  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x22b \
      --steps 50 --grad-compression int8_ef --fail-at 20
"""
from __future__ import annotations

import argparse

from ..configs import get_config
from ..core import AnalyticPlane, build_fleet_federation
from ..data import DatasetSpec, FederatedDataLoader, SyntheticTokens
from ..train import (AdamWConfig, FailureInjector, FederatedCheckpointer,
                     Trainer)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a node failure at this step")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    fed = build_fleet_federation(num_pods=args.pods, hosts_per_pod=8)
    spec = DatasetSpec("launch", vocab_size=cfg.vocab_size,
                       tokens_per_shard=1 << 16, num_shards=16)
    SyntheticTokens(spec).publish(fed.origins[0])
    plane = AnalyticPlane(fed)
    loader = FederatedDataLoader(plane, spec, global_batch=args.batch,
                                 seq_len=args.seq, site="pod0", worker=0)
    ck = FederatedCheckpointer(f"launch-{args.arch}", plane,
                               site="pod0", worker=1)
    trainer = Trainer(cfg, loader,
                      AdamWConfig(lr=args.lr, warmup_steps=5,
                                  total_steps=max(args.steps, 10)),
                      checkpointer=ck,
                      checkpoint_every=args.checkpoint_every,
                      grad_compression=args.grad_compression)
    failure = FailureInjector([args.fail_at]) if args.fail_at >= 0 else None
    report = trainer.run(args.steps, failure=failure)
    print(f"arch={cfg.name} steps={report.steps_run} "
          f"loss {report.losses[0]:.3f}→{report.final_loss:.3f} "
          f"restarts={report.restarts} hit_rate={report.cache_hit_rate:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
