"""Serving launcher: weights via the federation, batched generate.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b \
      --requests 6 --max-new 12
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from ..configs import get_config
from ..core import AnalyticPlane, build_fleet_federation
from ..models import init_lm
from ..serve import Request, ServeEngine
from ..train import FederatedCheckpointer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=96)
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(get_config(args.arch, smoke=True),
                              dtype="float32")
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)

    # Publish → restore through the data plane (weight distribution).
    fed = build_fleet_federation(num_pods=1, hosts_per_pod=4)
    plane = AnalyticPlane(fed)
    ck = FederatedCheckpointer("serve", plane, site="pod0", worker=0)
    ck.save(0, params)
    params, st = FederatedCheckpointer(
        "serve", plane, site="pod0", worker=1).restore(0, like=params)
    print(f"weights via federation: {st.bytes / 1e6:.1f} MB, "
          f"hits={st.cache_hits} misses={st.cache_misses}")

    engine = ServeEngine(cfg, params, batch_size=args.batch,
                         max_seq=args.max_seq, plane=plane,
                         site="pod0", worker=1)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=8),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    engine.generate(reqs)
    print(f"served {len(reqs)} requests: {engine.stats.prefills} prefills, "
          f"{engine.stats.decode_steps} decode steps, "
          f"{engine.stats.tokens_out} tokens")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
