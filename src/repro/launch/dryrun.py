import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces a JSON artifact with:
  * ``memory_analysis`` — per-device bytes (proves the cell fits HBM),
  * ``cost_analysis``   — HLO FLOPs / bytes accessed (§Roofline numerators),
  * ``collectives``     — per-op-kind operand bytes parsed from the
    compiled HLO (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), the collective-roofline numerator.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
      --shape train_4k --mesh single --out benchmarks/artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, get_config, list_archs, shapes_for
from ..sharding.rules import make_rules
from ..train.optimizer import AdamWConfig
from ..train.step import (build_decode_step, build_prefill_step,
                          build_train_step)
from .mesh import make_production_mesh

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "artifacts" / "dryrun"

# Moment precision per arch size (DESIGN.md §5): ≥30 B params → bf16
# moments so optimizer state fits a 16 GB/chip single pod.
def opt_config_for(cfg) -> AdamWConfig:
    big = cfg.param_count() > 30e9
    return AdamWConfig(moment_dtype="bfloat16" if big else "float32")


_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str):
    """Per-device wire bytes of every collective in the compiled HLO.

    Operand types are not printed inline in compiled HLO text, so bytes are
    derived from the *result* shape with a per-kind wire model (ring
    algorithms, g = replica-group size):
      all-gather: recv ≈ result·(g−1)/g            (result is the gathered buf)
      all-reduce: send+recv ≈ 2·result·(g−1)/g
      reduce-scatter: send ≈ result·(g−1)          (result is the scattered buf)
      all-to-all / collective-permute: ≈ result.
    ``depth`` counts "while/body" frames in the op's metadata — collectives
    at depth ≥ 1 execute once per scan iteration, so the roofline multiplies
    them by the model's group-scan trip count (§Roofline methodology).
    """
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        result = _shape_bytes(dtype, dims)
        g = 2
        gm = _GROUPS_RE.search(line)
        if gm:
            g = max(int(gm.group(2)), 1)
        if kind == "all-gather":
            wire = result * (g - 1) // max(g, 1)
        elif kind == "all-reduce":
            wire = 2 * result * (g - 1) // max(g, 1)
        elif kind == "reduce-scatter":
            wire = result * (g - 1)
        else:
            wire = result
        depth = line.count("while/body")
        key = f"{kind}@loop" if depth else kind
        rec = out.setdefault(key, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += wire
    return out


def build_cell(arch: str, shape_name: str, mesh, microbatches: int = 1,
               overrides=None, pad_heads: int = 0):
    cfg = get_config(arch)
    if pad_heads:
        cfg = dataclasses.replace(cfg, padded_heads=pad_heads)
    shape = SHAPES[shape_name]
    rules = make_rules(cfg, mesh, global_batch=shape.global_batch,
                       overrides=overrides)
    if shape.kind == "train":
        art = build_train_step(cfg, rules, opt_config_for(cfg),
                               shape.global_batch, shape.seq_len,
                               microbatches=microbatches)
    elif shape.kind == "prefill":
        art = build_prefill_step(cfg, rules, shape.global_batch,
                                 shape.seq_len)
    else:
        art = build_decode_step(cfg, rules, shape.global_batch,
                                shape.seq_len)
    return cfg, shape, art


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: Path = ARTIFACT_DIR, microbatches: int = 1,
             overrides=None, tag: str = "", pad_heads: int = 0) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    with mesh:
        cfg, shape, art = build_cell(arch, shape_name, mesh, microbatches,
                                     overrides, pad_heads)
        jitted = jax.jit(art.fn, donate_argnums=art.donate_argnums,
                         out_shardings=art.out_shardings)
        lowered = jitted.lower(*art.abstract_inputs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    n_dev = mesh.size

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape),
        "tag": tag,
        "kind": shape.kind,
        "global_batch": shape.global_batch,
        "seq_len": shape.seq_len,
        "microbatches": microbatches,
        "devices": n_dev,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "scan_groups": cfg.num_groups() * max(microbatches, 1),
        "pad_heads": cfg.padded_heads,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "cost": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
        },
        "collectives": coll,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}_{shape_name}_{mesh_kind}" + (f"_{tag}" if tag else "")
    (out_dir / f"{name}.json").write_text(json.dumps(record, indent=1))
    return record


def all_cells():
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            for mesh_kind in ("single", "multi"):
                yield arch, shape.name, mesh_kind


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--pad-heads", type=int, default=0)
    ap.add_argument("--override", default="",
                    help="sharding overrides: k=v,k=v (v: mesh axis, "
                         "'none', or '+'-joined tuple)")
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    args = ap.parse_args(argv)
    out = Path(args.out)

    cells = list(all_cells()) if args.all else \
        [(args.arch, args.shape, args.mesh)]
    failures = 0
    for arch, shape, mesh_kind in cells:
        name = f"{arch}_{shape}_{mesh_kind}"
        if args.skip_existing and (out / f"{name}.json").exists():
            print(f"SKIP {name}", flush=True)
            continue
        try:
            overrides = None
            if args.override:
                overrides = {}
                for kv in args.override.split(","):
                    k, v = kv.split("=")
                    overrides[k] = None if v == "none" else \
                        (tuple(v.split("+")) if "+" in v else v)
            rec = run_cell(arch, shape, mesh_kind, out,
                           microbatches=args.microbatches, tag=args.tag,
                           overrides=overrides, pad_heads=args.pad_heads)
            peak = rec["memory"]["peak_bytes"] / 2 ** 30
            print(f"OK   {name}: peak={peak:.2f} GiB/dev "
                  f"flops={rec['cost']['flops']:.3e} "
                  f"compile={rec['compile_s']}s", flush=True)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"FAIL {name}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
