"""§Roofline — three-term roofline per (arch × shape × mesh).

  compute_s    = FLOPs_per_device / peak_FLOPs          (197 TF bf16)
  memory_s     = HBM_bytes_per_device / HBM_bw           (819 GB/s)
  collective_s = collective_bytes_per_device / link_bw   (50 GB/s ICI)

Two sources, reported side by side:
  * **analytic** (primary) — ``analytic_cost.cell_cost``: exact trip
    counts for the scanned stacks (XLA's cost_analysis counts each while
    body once — a known limitation — so scanned models under-report by
    ~num_layers; validated against cost_analysis on unrolled configs in
    tests/test_roofline.py);
  * **measured** — cost_analysis() FLOPs (body-once) and HLO-parsed
    collective bytes with loop-depth attribution: collectives inside the
    group scan are multiplied by the scan trip count.

The dominant analytic term is the bottleneck; useful-compute ratio =
MODEL_FLOPS / analytic FLOPs exposes remat/dispatch/masking waste.
"""
from __future__ import annotations

import glob
import json
import types
from pathlib import Path

from repro.configs.base import SHAPES, get_config
from repro.sharding.rules import make_rules

from . import analytic_cost

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ('roofline.json',)
DRYRUN = ARTIFACTS / "dryrun"


def model_flops_per_dev(rec: dict) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode), per device."""
    n = rec["active_params"]
    if rec["kind"] == "train":
        total = 6.0 * n * rec["global_batch"] * rec["seq_len"]
    elif rec["kind"] == "prefill":
        total = 2.0 * n * rec["global_batch"] * rec["seq_len"]
    else:
        total = 2.0 * n * rec["global_batch"]
    return total / rec["devices"]


def _stub_mesh(rec: dict):
    return types.SimpleNamespace(shape=dict(rec["mesh_shape"]))


def measured_collective_bytes(rec: dict) -> float:
    g = rec.get("scan_groups", 1)
    total = 0.0
    for kind, v in rec["collectives"].items():
        total += v["bytes"] * (g if kind.endswith("@loop") else 1)
    return total


def analyse(rec: dict) -> dict:
    import dataclasses
    cfg = get_config(rec["arch"])
    # the artifact records the padding it was *compiled* with (0 for
    # pre-padding artifacts) — never inherit the config default here
    cfg = dataclasses.replace(cfg, padded_heads=rec.get("pad_heads", 0))
    shape = SHAPES[rec["shape"]]
    rules = make_rules(cfg, _stub_mesh(rec),
                       global_batch=shape.global_batch)
    ac = analytic_cost.cell_cost(cfg, shape, rec["mesh"], rules.table)
    meas_coll = measured_collective_bytes(rec)
    terms = {
        "compute": ac["flops_per_dev"] / PEAK_FLOPS,
        "memory": ac["hbm_bytes_per_dev"] / HBM_BW,
        # collective term: HLO-parsed wire bytes (loop-depth attributed) —
        # the compiled truth; the analytic estimate is kept for comparison.
        "collective": meas_coll / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_dev(rec)
    useful = mf / ac["flops_per_dev"] if ac["flops_per_dev"] else 0.0
    total = sum(terms.values())
    # Roofline fraction: what share of a perfectly-overlapped step the
    # dominant resource accounts for (higher = closer to that roofline).
    frac = terms[dominant] / total if total else 0.0
    return {
        "cell": f'{rec["arch"]}|{rec["shape"]}|{rec["mesh"]}',
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "tag": rec.get("tag", ""),
        **{f"{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "roofline_fraction": frac,
        "useful_compute_ratio": useful,
        "measured_flops_bodyonce": rec["cost"]["flops"],
        "measured_collective_bytes": meas_coll,
        "analytic_collective_s":
            ac["collective_bytes_per_dev"] / LINK_BW,
        "peak_gib": rec["memory"]["peak_bytes"] / 2 ** 30,
        "breakdown": ac["breakdown"],
        "advice": advice(dominant, useful),
    }


def advice(dominant: str, useful: float) -> str:
    if dominant == "compute" and useful < 0.5:
        return ("compute-bound, <50% useful FLOPs: cut remat recompute / "
                "MoE dispatch / masked-attention waste")
    if dominant == "compute":
        return "compute-bound: raise per-step batch or quantize matmuls"
    if dominant == "memory":
        return ("memory-bound: fuse elementwise chains, raise arithmetic "
                "intensity, shrink optimizer/cache traffic")
    return ("collective-bound: reshard to cut FSDP gathers, overlap "
            "collectives with compute, compress gradients")


def load_records(tag: str = ""):
    recs = []
    for f in sorted(glob.glob(str(DRYRUN / "*.json"))):
        rec = json.load(open(f))
        if rec.get("tag", "") == tag:
            recs.append(rec)
    return recs


def run(verbose: bool = False, tag: str = ""):
    recs = load_records(tag)
    rows = [analyse(r) for r in recs]
    (ARTIFACTS / "roofline.json").write_text(json.dumps(rows, indent=1))
    if verbose:
        print(f'  {"cell":46s} {"compute":>9s} {"memory":>9s} '
              f'{"collect":>9s} dom  {"useful":>6s} {"peakGiB":>8s}')
        for r in sorted(rows, key=lambda r: r["cell"]):
            print(f'  {r["cell"]:46s} {r["compute_s"]:9.4f} '
                  f'{r["memory_s"]:9.4f} {r["collective_s"]:9.4f} '
                  f'{r["dominant"][:4]:4s} {r["useful_compute_ratio"]:6.2f} '
                  f'{r["peak_gib"]:8.2f}')
    n_comp = sum(1 for r in rows if r["dominant"] == "compute")
    n_mem = sum(1 for r in rows if r["dominant"] == "memory")
    n_coll = sum(1 for r in rows if r["dominant"] == "collective")
    return [("roofline.cells", 0.0,
             f"n={len(rows)}_compute={n_comp}_mem={n_mem}_coll={n_coll}")]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
