"""CI perf regression gate: current bench artifacts vs committed baseline.

``baseline.json`` records the metrics the fleet has already won — the
sweep's batched-vs-serial speedup, the batched waterfill's solve count,
the outage-storm solve coalescing — with a direction and a tolerance
per metric.  This script re-derives the same metrics from the artifacts
a fresh bench run just wrote (``benchmarks/artifacts/*.json``), prints a
readable diff, and exits non-zero when any metric regressed past its
tolerance (default: 25%) or fell through its hard floor.

  PYTHONPATH=src python -m benchmarks.check_regression           # gate
  PYTHONPATH=src python -m benchmarks.check_regression --update  # re-baseline

Metric semantics:

* ``direction: "min"`` — bigger is better; fail when
  ``current < value * (1 - tolerance)`` (or ``< floor``, if set).
* ``direction: "max"`` — smaller is better; fail when
  ``current > value * (1 + tolerance)`` (or ``> ceiling``, if set).

A metric whose artifact is missing fails the gate: the harness deletes
a failed bench's artifacts precisely so stale numbers cannot pass here.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

HERE = Path(__file__).parent
BASELINE = HERE / "baseline.json"
ARTIFACTS = HERE / "artifacts"

DEFAULT_TOLERANCE = 0.25

# metric name -> (artifact file, extractor)
EXTRACTORS: Dict[str, Tuple[str, Callable[[dict], float]]] = {
    "sweep_speedup": ("sweep.json", lambda a: a["speedup"]),
    "sweep_cells": ("sweep.json", lambda a: a["cells"]),
    "sweep_batched_cells": (
        "sweep.json", lambda a: a["batched"]["batched_cells"]),
    "sweep_solve_calls": (
        "sweep.json", lambda a: a["batched"]["solver"]["solve_calls"]),
    "sweep_parity_mismatches": (
        "sweep.json", lambda a: len(a["parity"]["mismatches"])),
    "eviction_sweep_speedup": (
        "sweep.json", lambda a: a["eviction"]["speedup"]),
    "eviction_sweep_serial_cells": (
        "sweep.json", lambda a: a["eviction"]["batched"]["serial_cells"]),
    "eviction_sweep_parity_mismatches": (
        "sweep.json", lambda a: len(a["eviction"]["parity"]["mismatches"])),
    "tier_sweep_speedup": ("tiers.json", lambda a: a["speedup"]),
    "tier_sweep_cells": ("tiers.json", lambda a: a["cells"]),
    "tier_sweep_serial_cells": (
        "tiers.json", lambda a: a["batched"]["serial_cells"]),
    "tier_parity_mismatches": (
        "tiers.json", lambda a: len(a["parity"]["mismatches"])),
    "tier_egress_reduction": (
        "tiers.json", lambda a: a["egress"]["reduction"]),
    "storm_coalescing_ratio": (
        "outage_storm.json", lambda a: a["storm"]["coalescing_ratio"]),
    "storm_reallocations": (
        "outage_storm.json", lambda a: a["storm"]["reallocations"]),
    "overload_p99": (
        "overload.json",
        lambda a: a["profile"][str(a["derived"]["overload_factor"])]
        ["throttled"]["p99_seconds"]),
    "overload_shed_rate": (
        "overload.json", lambda a: a["derived"]["shed_rate"]),
    "overload_goodput_ratio": (
        "overload.json", lambda a: a["derived"]["goodput_ratio_throttled"]),
    "overload_p99_degradation_unthrottled": (
        "overload.json",
        lambda a: a["derived"]["p99_degradation_unthrottled"]),
    "plan_lru_forward_error": (
        "plan.json", lambda a: a["forward"]["lru_max_abs_error"]),
    "plan_fifo_forward_error": (
        "plan.json", lambda a: a["forward"]["fifo_max_abs_error"]),
    "plan_savings_vs_uniform": (
        "plan.json", lambda a: a["planner"]["savings_vs_uniform"]),
    "plan_feasible": (
        "plan.json",
        lambda a: 1.0 if a["verification"]["feasible"] else 0.0),
    "train_restart_storm_seconds": (
        "train_traffic.json", lambda a: a["restart"]["cached"]["seconds"]),
    "train_egress_reduction": (
        "train_traffic.json", lambda a: a["restart"]["egress_reduction"]),
    "train_parity_mismatches": (
        "train_traffic.json", lambda a: len(a["parity"]["mismatches"])),
    "fedlint_violations": (
        "fedlint.json", lambda a: a["violations"]),
    "fedlint_suppressions": (
        "fedlint.json", lambda a: a["suppressed"]),
    "fedlint_sanitizer_checks": (
        "fedlint.json", lambda a: a["sanitizer"]["checks"]),
}


def current_metrics(artifacts: Path = ARTIFACTS) -> Dict[str, float]:
    """Extract every known metric whose artifact exists."""
    cache: Dict[str, Optional[dict]] = {}
    out: Dict[str, float] = {}
    for name, (fname, extract) in EXTRACTORS.items():
        if fname not in cache:
            path = artifacts / fname
            cache[fname] = (json.loads(path.read_text())
                            if path.exists() else None)
        art = cache[fname]
        if art is not None:
            out[name] = float(extract(art))
    return out


def compare(baseline: Dict, current: Dict[str, float]
            ) -> Tuple[List[str], List[tuple]]:
    """Evaluate every baseline metric against the current run.

    Returns ``(failures, rows)`` where rows are
    ``(metric, baseline, current, bound, verdict)`` for the diff table.
    """
    failures: List[str] = []
    rows: List[tuple] = []
    for name, spec in baseline["metrics"].items():
        base = float(spec["value"])
        direction = spec.get("direction", "min")
        tol = float(spec.get("tolerance", DEFAULT_TOLERANCE))
        cur = current.get(name)
        if cur is None:
            fname = EXTRACTORS.get(name, ("<unknown>",))[0]
            failures.append(f"{name}: no current value "
                            f"(artifact {fname} missing or stale-discarded)")
            rows.append((name, base, None, None, "MISSING"))
            continue
        if direction == "min":
            bound = base * (1.0 - tol)
            floor = spec.get("floor")
            if floor is not None:
                bound = max(bound, float(floor))
            ok = cur >= bound
            verdict = "ok" if ok else "REGRESSED"
            if not ok:
                failures.append(
                    f"{name}: {cur:.4g} < allowed minimum {bound:.4g} "
                    f"(baseline {base:.4g}, tolerance {tol:.0%}"
                    + (f", floor {floor}" if floor is not None else "")
                    + ")")
        else:
            bound = base * (1.0 + tol)
            ceiling = spec.get("ceiling")
            if ceiling is not None:
                bound = min(bound, float(ceiling))
            ok = cur <= bound
            verdict = "ok" if ok else "REGRESSED"
            if not ok:
                failures.append(
                    f"{name}: {cur:.4g} > allowed maximum {bound:.4g} "
                    f"(baseline {base:.4g}, tolerance {tol:.0%}"
                    + (f", ceiling {ceiling}" if ceiling is not None else "")
                    + ")")
        rows.append((name, base, cur, bound, verdict))
    return failures, rows


def format_table(rows: List[tuple]) -> str:
    header = f"{'metric':<28} {'baseline':>12} {'current':>12} " \
             f"{'bound':>12}  verdict"
    lines = [header, "-" * len(header)]
    for name, base, cur, bound, verdict in rows:
        cur_s = f"{cur:>12.4g}" if cur is not None else f"{'--':>12}"
        bound_s = f"{bound:>12.4g}" if bound is not None else f"{'--':>12}"
        lines.append(f"{name:<28} {base:>12.4g} {cur_s} {bound_s}  {verdict}")
    return "\n".join(lines)


def update_baseline(baseline: Dict, current: Dict[str, float],
                    path: Path = BASELINE) -> List[str]:
    """Rewrite every baseline value from ``current``.

    Refuses (writes nothing, returns the missing names) when any gated
    metric has no current value — a partial update would silently keep
    values from an unknown earlier run, which is exactly the staleness
    the artifact-discard machinery exists to prevent."""
    missing = [name for name in baseline["metrics"] if name not in current]
    if missing:
        return missing
    for name, spec in baseline["metrics"].items():
        spec["value"] = current[name]
    path.write_text(json.dumps(baseline, indent=1) + "\n")
    return []


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.check_regression",
        description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", type=Path, default=BASELINE)
    ap.add_argument("--artifacts", type=Path, default=ARTIFACTS)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline's values from the current "
                         "artifacts instead of gating")
    args = ap.parse_args(argv)
    baseline = json.loads(args.baseline.read_text())
    current = current_metrics(args.artifacts)
    if args.update:
        missing = update_baseline(baseline, current, args.baseline)
        if missing:
            print("baseline NOT updated — no current value for: "
                  + ", ".join(missing)
                  + " (rerun the gate-profile benches first)",
                  file=sys.stderr)
            return 1
        print(f"baseline updated with {len(baseline['metrics'])} metrics")
        return 0
    failures, rows = compare(baseline, current)
    print(format_table(rows))
    if failures:
        print(f"\n{len(failures)} metric(s) regressed past tolerance:",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nall {len(rows)} gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
