"""Outage storms through simulator-native federation clients.

Every download here routes through the *real* client chain —
``StashClient._ranked_caches`` / ``CacheGroup.route`` ring ownership,
dead-member failover, origin fallback — under max-min link contention
(:mod:`repro.core.simclient`).  Three experiments, all writing
``artifacts/outage_storm.json``:

* **storm** — a fleet-wide restart storm (every worker pulls the same
  checkpoint at t=0) with a cache outage wave mid-run: victims die while
  pulls are in flight (mid-transfer failover) and a second request wave
  arrives while they are still down (ring-chain failover at route time).
  Also the event-loop scaling probe: ``flow_events`` is the number of
  solves the per-arrival loop would have run; ``coalescing_ratio`` is
  how many of them the same-timestamp batching actually avoided.
* **churn** — ring vs modulo routing *with link contention*: a Zipf
  trace against one HA cache group while two members cold-restart.
  Consistent hashing remaps only the dead members' keyspace; the
  modulo baseline reshuffles nearly every key twice (death + recovery),
  which shows up as origin egress and lost hit rate.
* **rolling** — a production-shaped multi-site trace replayed across a
  rolling upgrade of every pod cache, with hedged fetches picking up
  the stragglers.

Artifact schema (see docs/BENCHMARKS.md): each experiment maps to a
dict of scalar gauges — ``ScenarioReport.summary()`` keys plus the
experiment's own parameters — so runs diff cleanly.  Every experiment
is a declarative :class:`ScenarioSpec` executed by
:func:`~repro.core.api.run_scenario` on the simulated engine.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (FederationSpec, OutageSchedule, ScenarioSpec,
                        WorkloadSpec, run_scenario, storm_workload)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ('outage_storm.json',)
GB = 1e9


# ---------------------------------------------------------------------------
# Restart storm: ≥500 pods, outage wave mid-run, two request waves
# ---------------------------------------------------------------------------
def _storm_scenario(pods: int = 1000, hosts: int = 2,
                    ckpt_gb: float = 2.0, kills: int = 8) -> dict:
    sites = [f"pod{p}" for p in range(pods)]
    path = "/ckpt/run1/step_01000/params.npy"
    # Wave 1 at t=0 (the storm proper); wave 2 arrives while the victims
    # are still down, so CacheGroup.route sees dead primaries live.
    reqs = storm_workload(sites, path=path, size=int(ckpt_gb * GB),
                          at=0.0, workers_per_site=hosts)
    reqs += storm_workload(sites[:max(kills * 4, 16)], path=path, at=8.0,
                           size=int(ckpt_gb * GB), workers_per_site=hosts)
    victims = [f"pod{p}/cache" for p in range(kills)]
    spec = ScenarioSpec(
        name="outage_storm/storm",
        federation=FederationSpec.fleet(num_pods=pods, hosts_per_pod=hosts),
        workload=reqs,
        outages=OutageSchedule.restart_storm(victims, at=1.0, downtime=30.0,
                                             stagger=0.5, cold=True),
        solver="auto")
    t0 = time.perf_counter()
    rep = run_scenario(spec)
    wall = time.perf_counter() - t0
    out = rep.summary()
    out.update({
        "pods": pods, "hosts_per_pod": hosts, "kills": kills,
        "ckpt_bytes": int(ckpt_gb * GB),
        "wall_seconds": wall,
        # per-arrival baseline: the old loop solved once per flow event
        "baseline_reallocations": rep.flow_events,
    })
    return out


# ---------------------------------------------------------------------------
# Contended churn: ring vs modulo while group members cold-restart
# ---------------------------------------------------------------------------
def _contended_churn(replicas: int = 6, hosts: int = 8,
                     n_requests: int = 1200, working_set: int = 96) -> dict:
    out: dict = {"replicas": replicas, "requests": n_requests,
                 "working_set": working_set}
    for router in ("ring", "modulo"):
        fed_spec = FederationSpec.fleet(num_pods=1, hosts_per_pod=hosts,
                                        cache_replicas=replicas)
        fed = fed_spec.build()
        members = [c.name for c in fed.groups["pod0"].members]
        spec = ScenarioSpec(
            name=f"outage_storm/churn/{router}",
            federation=fed_spec,
            workload=WorkloadSpec(kind="zipf", sites=["pod0"],
                                  n_requests=n_requests,
                                  working_set=working_set, seed=7,
                                  duration=600.0),
            outages=OutageSchedule.restart_storm(members[:2], at=200.0,
                                                 downtime=120.0,
                                                 stagger=30.0, cold=True),
            router=router)
        rep = run_scenario(spec, federation=fed)
        s = rep.summary()
        out[router] = {k: s[k] for k in
                       ("hit_rate", "origin_egress_bytes", "p95_seconds",
                        "cache_failovers", "group_failovers",
                        "origin_fallbacks")}
    out["origin_offload_vs_modulo"] = (
        out["modulo"]["origin_egress_bytes"]
        / max(out["ring"]["origin_egress_bytes"], 1))
    return out


# ---------------------------------------------------------------------------
# Rolling upgrade across a multi-site trace, hedged fetches on
# ---------------------------------------------------------------------------
def _rolling_upgrade(pods: int = 12, hosts: int = 4,
                     n_requests: int = 1800) -> dict:
    primaries = [f"pod{p}/cache" for p in range(pods)]
    # hedge-at-p95: the trace's tail sits just above half a second, so
    # only genuine stragglers (big files queued behind an origin pull
    # during an upgrade window) trigger the backup race.
    spec = ScenarioSpec(
        name="outage_storm/rolling",
        federation=FederationSpec.fleet(num_pods=pods, hosts_per_pod=hosts,
                                        cache_replicas=2),
        workload=WorkloadSpec(kind="zipf", n_requests=n_requests,
                              working_set=64, seed=13, duration=600.0),
        outages=OutageSchedule.rolling_upgrade(primaries, start=60.0,
                                               downtime=20.0, gap=10.0,
                                               cold=True),
        hedge_after=0.5)
    rep = run_scenario(spec)
    out = rep.summary()
    out.update({"pods": pods, "hosts_per_pod": hosts,
                "upgraded": len(primaries)})
    return out


def run(pods: int = 1000, hosts: int = 2, kills: int = 8,
        quick: bool = False, verbose: bool = False):
    if quick:
        storm = _storm_scenario(pods=min(pods, 60), hosts=1, kills=2)
        churn = _contended_churn(replicas=4, hosts=4, n_requests=300,
                                 working_set=32)
        rolling = _rolling_upgrade(pods=4, hosts=2, n_requests=240)
    else:
        storm = _storm_scenario(pods=pods, hosts=hosts, kills=kills)
        churn = _contended_churn()
        rolling = _rolling_upgrade()
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "outage_storm.json").write_text(json.dumps({
        "storm": storm, "churn": churn, "rolling": rolling}, indent=1))
    if verbose:
        print(f"  storm: {storm['pods']} pods, {storm['requests']} reqs, "
              f"sim {storm['sim_seconds']:.1f}s in "
              f"{storm['wall_seconds']:.1f}s wall, "
              f"coalesce {storm['coalescing_ratio']:.0f}x "
              f"({storm['reallocations']} solves vs "
              f"{storm['baseline_reallocations']} per-arrival), "
              f"failovers {storm['cache_failovers']}+"
              f"{storm['group_failovers']}")
        print(f"  churn: ring hit {churn['ring']['hit_rate']:.3f} vs "
              f"modulo {churn['modulo']['hit_rate']:.3f}, origin offload "
              f"{churn['origin_offload_vs_modulo']:.2f}x")
        print(f"  rolling: hit {rolling['hit_rate']:.3f}, hedged "
              f"{rolling['hedged_fetches']}, p95 "
              f"{rolling['p95_seconds']:.1f}s")
    return [
        ("outage_storm.storm", storm["wall_seconds"] * 1e6,
         f"coalesce={storm['coalescing_ratio']:.0f}x@"
         f"{storm['pods']}pods,failovers="
         f"{storm['cache_failovers'] + storm['group_failovers']}"),
        ("outage_storm.storm_solves", float(storm["reallocations"]),
         f"baseline={storm['baseline_reallocations']}"),
        ("outage_storm.churn", churn["ring"]["hit_rate"] * 1e6,
         f"offload_vs_modulo={churn['origin_offload_vs_modulo']:.2f}x"),
        ("outage_storm.rolling", rolling["p95_seconds"] * 1e6,
         f"hedged={rolling['hedged_fetches']},"
         f"hit={rolling['hit_rate']:.3f}"),
    ]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
