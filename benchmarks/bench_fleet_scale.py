"""Fleet-scale scenario sweep: 1000+ sites, churn, storms, policies.

Three experiments, all writing ``artifacts/fleet_scale.json``:

* **solver** — a hot-object storm across a 1000-pod fleet (every worker
  pulls the same checkpoint at t=0).  The full scenario runs end-to-end
  on the vectorized max-min solver (``repro.kernels.maxmin``); at peak
  concurrency the scalar waterfilling loop and the vectorized solver are
  timed head-to-head on the identical flow state, and a mid-size storm
  (where the scalar loop is still feasible) is run to completion under
  both solvers for an end-to-end wall-clock comparison.
* **churn** — a Zipf working set served by an HA cache group while
  members die one by one.  Consistent-hash routing remaps only the dead
  member's keyspace share; the modulo-hash baseline reshuffles nearly
  everything, which is the difference between a blip and an origin storm.
  (This replay drives the cache state machines directly — the *contended*
  ring-vs-modulo comparison, with routing through the real client chain
  under max-min link sharing, lives in ``bench_outage_storm.py``.)
* **policies** — the same production-shaped workload (Table 2 sizes,
  Zipf popularity) replayed through each eviction policy at equal
  capacity, reported via the monitoring pipeline's per-policy table.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (CacheGroup, CacheServer, Coord, FluidFlowSim,
                        MonitorCollector, Payload, Topology,
                        build_fleet_federation, fnv1a64, generate_workload,
                        stash_download)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ('fleet_scale.json',)
GB = 1e9


# ---------------------------------------------------------------------------
# Solver: 1000-site storm, scalar vs vectorized waterfilling
# ---------------------------------------------------------------------------
def _build_storm(pods: int, hosts: int, ckpt_gb: float, solver: str):
    fed = build_fleet_federation(num_pods=pods, hosts_per_pod=hosts)
    origin = fed.origins[0]
    meta = origin.put_object("/ckpt/run1/step_01000/params.npy",
                             int(ckpt_gb * GB))
    sim = FluidFlowSim(fed.topology, fed.net, solver=solver)
    redirector = fed.redirectors.members[0].node.name
    for p in range(pods):
        cache = fed.caches[f"pod{p}/cache"]
        for h in range(hosts):
            wnode = fed.client(f"pod{p}", h).node.name
            sim.spawn(stash_download(sim, wnode, cache, origin.node.name,
                                     redirector, meta,
                                     fed.geoip.lookup_latency))
    return fed, sim


def _solver_e2e(pods: int = 200, hosts: int = 4,
                ckpt_gb: float = 1.0) -> dict:
    """Identical mid-size storm under both solvers, timed to completion."""
    out = {"pods": pods, "hosts_per_pod": hosts}
    for solver in ("scalar", "vector"):
        _, sim = _build_storm(pods, hosts, ckpt_gb, solver)
        t0 = time.perf_counter()
        out[f"{solver}_sim_seconds"] = sim.run()
        out[f"{solver}_wall_seconds"] = time.perf_counter() - t0
    out["e2e_speedup"] = (out["scalar_wall_seconds"]
                          / max(out["vector_wall_seconds"], 1e-12))
    return out


def _solver_storm(pods: int = 1000, hosts: int = 2,
                  ckpt_gb: float = 2.0, reps: int = 3) -> dict:
    fed, sim = _build_storm(pods, hosts, ckpt_gb, solver="vector")
    # Advance to peak concurrency, then time both solvers on the exact
    # same flow state (rates are recomputed identically either way).
    sim.run(until=0.05)
    peak_flows = len(sim.active)
    t_vec = []
    for _ in range(reps):
        t0 = time.perf_counter()
        sim._reallocate_vector()
        t_vec.append(time.perf_counter() - t0)
    t_sca = []
    for _ in range(reps):
        t0 = time.perf_counter()
        sim._reallocate_scalar()
        t_sca.append(time.perf_counter() - t0)
    vec_s, sca_s = min(t_vec), min(t_sca)
    # ... and complete the 1000-site scenario on the vectorized solver.
    t0 = time.perf_counter()
    storm_seconds = sim.run()
    wall = time.perf_counter() - t0
    return {
        "pods": pods, "hosts_per_pod": hosts, "ckpt_bytes": int(ckpt_gb * GB),
        "peak_flows": peak_flows,
        "scalar_solve_seconds": sca_s,
        "vector_solve_seconds": vec_s,
        "solver_speedup": sca_s / max(vec_s, 1e-12),
        "storm_sim_seconds": storm_seconds,
        "storm_wall_seconds": wall,
        "reallocations": sim.reallocations,
        # per-arrival baseline vs the same-timestamp solve coalescing
        "flow_events": sim.flow_events,
        "coalescing_ratio": sim.flow_events / max(sim.reallocations, 1),
        "origin_egress_bytes": sum(c.stats.bytes_from_origin
                                   for c in fed.caches.values()),
    }


# ---------------------------------------------------------------------------
# Churn: consistent-hash vs modulo routing while caches die
# ---------------------------------------------------------------------------
def _mini_cache(name: str, capacity: float,
                monitor: MonitorCollector = None,
                policy: str = "lru") -> CacheServer:
    topo = Topology()
    topo.add_site("s")
    node = topo.add_node(name, Coord("s"), 1e10)
    return CacheServer(name, node, int(capacity), monitor=monitor,
                       policy=policy)


def _replay(cache: CacheServer, path: str, size: int, now: float) -> bool:
    """One request against the pure cache state machine.  True on hit."""
    cache.tick(now)
    if cache.lookup(path, 0) is not None:
        return True
    cache.admit(path, 0, Payload.synthetic(size, path, 0), object_size=size)
    return False


def _churn_scenario(n_caches: int = 8, n_requests: int = 6000,
                    working_set: int = 512, kills: int = 3) -> dict:
    reqs = generate_workload(["site"], n_requests, working_set=working_set,
                             seed=7)
    kill_at = {int(n_requests * (k + 1) / (kills + 1)): k
               for k in range(kills)}

    def run_mode(consistent: bool) -> dict:
        caches = [_mini_cache(f"c{i}", 256e9) for i in range(n_caches)]
        group = CacheGroup("churn", caches)
        hits = misses = moved = 0
        for i, r in enumerate(reqs):
            if i in kill_at:
                caches[kill_at[i]].available = False
            if consistent:
                target = next((c for c in group.route(r.path)
                               if c.available), None)
            else:
                alive = [c for c in caches if c.available]
                # fnv1a64, not builtin hash(): PYTHONHASHSEED would make
                # the baseline non-reproducible across runs.
                target = (alive[fnv1a64(r.path.encode()) % len(alive)]
                          if alive else None)
            if target is None:
                continue
            if _replay(target, r.path, r.size, r.time):
                hits += 1
            else:
                misses += 1
                moved += r.size
        return {"hit_rate": hits / max(hits + misses, 1),
                "origin_bytes": moved,
                "failovers": group.stats.failovers if consistent else None}

    ring = run_mode(True)
    modulo = run_mode(False)
    return {
        "caches": n_caches, "requests": n_requests, "kills": kills,
        "consistent_hash": ring, "modulo_hash": modulo,
        "origin_offload_vs_modulo":
            modulo["origin_bytes"] / max(ring["origin_bytes"], 1),
    }


# ---------------------------------------------------------------------------
# Policies: LRU / LFU / TTL / FIFO at equal capacity, Zipf workload
# ---------------------------------------------------------------------------
def _policy_sweep(n_requests: int = 6000, working_set: int = 512,
                  capacity_fraction: float = 0.05) -> dict:
    reqs = generate_workload(["site"], n_requests, working_set=working_set,
                             seed=11)
    total_bytes = sum({r.path: r.size for r in reqs}.values())
    capacity = capacity_fraction * total_bytes
    monitor = MonitorCollector()
    out = {}
    for policy in ("lru", "lfu", "ttl", "fifo"):
        cache = _mini_cache(f"cache-{policy}", capacity, monitor=monitor,
                            policy=policy)
        for r in reqs:
            _replay(cache, r.path, r.size, r.time)
        pkt = cache.report_usage()
        out[policy] = {"hit_rate": pkt.hit_rate,
                       "evictions": pkt.evictions,
                       "ttl_expired": pkt.ttl_expired,
                       "bytes_from_origin_equiv": cache.stats.misses}
    out["monitoring_policy_table"] = [
        {"policy": p, "caches": n, "hit_rate": hr, "evictions": ev,
         "ttl_expired": ttl, "admission_rejects": rej, "usage_bytes": ub}
        for p, n, hr, ev, ttl, rej, ub in monitor.policy_table()]
    return out


def run(pods: int = 1000, hosts: int = 2, e2e_pods: int = 200,
        verbose: bool = False):
    solver = _solver_storm(pods=pods, hosts=hosts)
    e2e = _solver_e2e(pods=e2e_pods)
    churn = _churn_scenario()
    policies = _policy_sweep()
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "fleet_scale.json").write_text(json.dumps({
        "solver": solver, "solver_e2e": e2e, "churn": churn,
        "policies": policies}, indent=1))
    if verbose:
        print(f"  storm: {solver['pods']} pods, peak {solver['peak_flows']} "
              f"flows, sim {solver['storm_sim_seconds']:.1f}s in "
              f"{solver['storm_wall_seconds']:.1f}s wall")
        print(f"  solve: scalar {solver['scalar_solve_seconds'] * 1e3:.1f}ms "
              f"vs vector {solver['vector_solve_seconds'] * 1e3:.1f}ms "
              f"({solver['solver_speedup']:.1f}x)")
        print(f"  e2e {e2e['pods']} pods: scalar "
              f"{e2e['scalar_wall_seconds']:.1f}s vs vector "
              f"{e2e['vector_wall_seconds']:.1f}s "
              f"({e2e['e2e_speedup']:.1f}x)")
        print(f"  churn: ring hit {churn['consistent_hash']['hit_rate']:.3f} "
              f"vs modulo {churn['modulo_hash']['hit_rate']:.3f}, origin "
              f"offload {churn['origin_offload_vs_modulo']:.2f}x")
        for p in ("lru", "lfu", "ttl", "fifo"):
            print(f"  policy {p}: hit {policies[p]['hit_rate']:.3f}")
    return [
        ("fleet_scale.solve_vector", solver["vector_solve_seconds"] * 1e6,
         f"speedup={solver['solver_speedup']:.1f}x@"
         f"{solver['peak_flows']}flows"),
        ("fleet_scale.solve_scalar", solver["scalar_solve_seconds"] * 1e6,
         f"pods={solver['pods']}"),
        ("fleet_scale.storm", solver["storm_wall_seconds"] * 1e6,
         f"sim_seconds={solver['storm_sim_seconds']:.1f}"),
        ("fleet_scale.e2e_vector", e2e["vector_wall_seconds"] * 1e6,
         f"speedup={e2e['e2e_speedup']:.1f}x@{e2e['pods']}pods"),
        ("fleet_scale.churn", churn["consistent_hash"]["hit_rate"] * 1e6,
         f"offload_vs_modulo={churn['origin_offload_vs_modulo']:.2f}x"),
        ("fleet_scale.policy_lfu", policies["lfu"]["hit_rate"] * 1e6,
         f"lru={policies['lru']['hit_rate']:.3f}"),
    ]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
