"""Micro-benchmarks of federation hot paths (cache ops, checksum, DES)."""
from __future__ import annotations

import time

from repro.core import (CacheServer, Coord, Payload, Topology, fnv1a64,
                        build_osg_federation)


def _time(fn, n: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def run(verbose: bool = False):
    topo = Topology()
    topo.add_site("s")
    node = topo.add_node("c", Coord("s"), 1e10)
    cache = CacheServer("c", node, capacity_bytes=1 << 30)
    payload = Payload.from_bytes(b"x" * 65536)
    i = [0]

    def admit():
        cache.admit("/f", i[0], payload)
        i[0] += 1

    t_admit = _time(admit, 2000)
    t_lookup = _time(lambda: cache.lookup("/f", i[0] - 1), 5000)
    data = b"q" * 65536
    t_fnv = _time(lambda: fnv1a64(data), 20)

    # DES event throughput: many flows through one shared uplink.
    from repro.core import FluidFlowSim
    fed = build_osg_federation()
    sim = FluidFlowSim(fed.topology, fed.net)

    def proc(w):
        yield sim.flow(fed.client("nebraska", w).node.name,
                       fed.origins[0].node.name, 1e8, streams=4)

    for w in range(100):
        sim.spawn(proc(w))
    t0 = time.perf_counter()
    sim.run()
    des_wall = time.perf_counter() - t0
    flows_per_s = sim.completed_flows / des_wall
    if verbose:
        print(f"  cache.admit {t_admit:.1f} us, lookup {t_lookup:.2f} us, "
              f"fnv1a64(64KB) {t_fnv:.0f} us, DES {flows_per_s:.0f} flows/s")
    return [("micro.cache_admit", t_admit, "64KB_chunks"),
            ("micro.cache_lookup", t_lookup, "lru_hit"),
            ("micro.fnv1a64_64k", t_fnv, "pure_python_oracle"),
            ("micro.des_flows", 1e6 / flows_per_s, "contended_uplink")]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
