"""Predictive planner bench: fit once, predict a held-out grid, invert.

Three phases, each priced and gated:

* **fit** — one ``run_sweep(fit=True)`` over a ``cache_capacity ×
  eviction_policy`` training grid.  The LRU models ride for free on the
  stack-distance kernel calls the exact sweep makes anyway; FIFO
  columns get a monotone interp model fitted to the training cells'
  exact hit rates (:func:`~repro.kernels.cache_model.fit_interp_model`).
* **forward** — a *held-out* sweep at the geometric midpoints of the
  training capacities, never seen by any fit, replayed exactly and
  compared against the model predictions cell by cell
  (:meth:`~repro.core.monitoring.SweepAggregator.model_residuals`).
  The LRU (differentiable) models must stay within 2% absolute
  hit-rate error; FIFO hit curves are genuine staircases (whole hot
  objects cross the capacity boundary at once), so their interp band
  is wider on the quick profile's coarse grid.
* **inverse** — fit a heterogeneous two-pod scenario (one hot skewed
  pod, one cold diffuse pod), run :func:`~repro.core.planner.
  plan_capacity` for a fleet hit-rate target, and ground-truth the
  recommendation with :func:`~repro.core.planner.verify_plan` (exact
  batched replay, bounded scale-up).  The plan must verify feasible
  AND beat uniform sizing on total bytes — the whole point of per-site
  capacity variables.

**Artifact** ``artifacts/plan.json`` (see docs/BENCHMARKS.md): the
training/held-out grids, per-policy max absolute forward error, the
residual table, the plan (capacities, savings, telemetry) and its
verification block.  The CI regression gate holds ``max_abs_error``
≤ 2%, ``savings_vs_uniform`` above its floor and ``feasible`` == 1.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (FederationSpec, PlannerSpec, ScenarioSpec,
                        SweepAggregator, SweepSpec, WorkloadSpec,
                        generate_workload, groups_for_federation,
                        plan_capacity, predict, run_sweep, verify_plan)
from repro.kernels.cache_model import fit_interp_model, predict_hit_rate

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ("plan.json",)

CAP_AXIS = "federation.cache_capacity"
POLICY_AXIS = "federation.eviction_policy"


def _chunk_hit(summary) -> float:
    refs = summary["cache_hits"] + summary["cache_misses"]
    return summary["cache_hits"] / max(refs, 1)


def forward_spec(quick: bool = False) -> ScenarioSpec:
    """Homogeneous fleet for the forward-accuracy grid.  The full
    profile uses a large working set so the FIFO staircase's individual
    steps are small enough for the interp model's 2% band."""
    return ScenarioSpec(
        name="plan-forward", engine="analytic",
        federation=FederationSpec.fleet(num_pods=2, hosts_per_pod=2,
                                        cache_capacity=2e9),
        workload=WorkloadSpec(kind="zipf",
                              n_requests=260 if quick else 900,
                              working_set=8 if quick else 64,
                              duration=600.0, seed=5))


def capacity_grids(quick: bool = False):
    """Training capacities and their geometric midpoints (held out)."""
    train = np.geomspace(4e8 if quick else 6e8,
                         2e10 if quick else 6e10,
                         7 if quick else 15)
    held = np.sqrt(train[:-1] * train[1:])
    return train, held


def planner_scenario(quick: bool = False) -> ScenarioSpec:
    """Two pods with very different locality — the configuration where
    per-site sizing should crush uniform sizing."""
    fed = FederationSpec.fleet(num_pods=2, hosts_per_pod=2,
                               cache_capacity=2e9)
    n0, n1 = (300, 80) if quick else (700, 150)
    wl = (generate_workload([fed.sites[0].name], n0, seed=0,
                            working_set=6, zipf_a=1.6)
          + generate_workload([fed.sites[1].name], n1, seed=1,
                              working_set=64, zipf_a=1.05))
    wl.sort(key=lambda r: r.time)
    return ScenarioSpec(name="plan-hetero", engine="analytic",
                        federation=fed, workload=wl)


TARGET_HIT_RATE = 0.5


def run(quick: bool = False, verbose: bool = False):
    train, held = capacity_grids(quick)
    base = forward_spec(quick)

    # --- fit: training sweep, models ride on the exact kernel calls
    t0 = time.perf_counter()
    train_rep = run_sweep(SweepSpec(name="plan-train", base=base, axes={
        CAP_AXIS: list(train), POLICY_AXIS: ["lru", "fifo"],
    }), fit=True)
    t_fit = time.perf_counter() - t0
    models = train_rep.fitted_models()
    fifo_cells = [(c.params[CAP_AXIS], _chunk_hit(c.summary))
                  for c in train_rep.cells
                  if c.params[POLICY_AXIS] == "fifo"]
    fifo_model = fit_interp_model([p[0] for p in fifo_cells],
                                  [p[1] for p in fifo_cells])

    # --- forward: exact replay of the held-out grid vs predictions
    t0 = time.perf_counter()
    held_rep = run_sweep(SweepSpec(name="plan-held", base=base, axes={
        CAP_AXIS: list(held), POLICY_AXIS: ["lru", "fifo"],
    }))
    t_held = time.perf_counter() - t0

    agg = SweepAggregator()
    for c in held_rep.cells:
        agg.add(c.params, {"hit_rate": _chunk_hit(c.summary)})

    def model_value(params):
        if params[POLICY_AXIS] == "lru":
            return predict(models, params[CAP_AXIS])["hit_rate"]
        return float(predict_hit_rate(fifo_model, params[CAP_AXIS]))

    residuals = agg.model_residuals(model_value)
    err = {"lru": 0.0, "fifo": 0.0}
    for params, _, _, residual in residuals:
        p = params[POLICY_AXIS]
        err[p] = max(err[p], abs(residual))
    max_abs_error = max(err.values())

    # --- inverse: heterogeneous fit -> plan -> exact-replay verify
    hetero = planner_scenario(quick)
    t0 = time.perf_counter()
    hetero_rep = run_sweep(SweepSpec(name="plan-hetero", base=hetero,
                                     axes={}), fit=True)
    t_hfit = time.perf_counter() - t0
    hmodels = hetero_rep.fitted_models()
    groups = groups_for_federation(hetero.federation.build(), hmodels)
    t0 = time.perf_counter()
    plan = plan_capacity(PlannerSpec(models=hmodels,
                                     target_hit_rate=TARGET_HIT_RATE,
                                     groups=groups))
    t_solve = time.perf_counter() - t0
    t0 = time.perf_counter()
    plan = verify_plan(plan, hetero)
    t_verify = time.perf_counter() - t0
    summary = plan.summary()

    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "plan.json").write_text(json.dumps({
        "quick": quick,
        "fit": {
            "wall_seconds": t_fit,
            "cells": len(train_rep.cells),
            "fit_streams": train_rep.solver.get("fit_streams", 0),
            "models": {k: m.kind for k, m in sorted(models.items())},
        },
        "forward": {
            "train_capacities": [float(c) for c in train],
            "held_capacities": [float(c) for c in held],
            "wall_seconds": t_held,
            "max_abs_error": max_abs_error,
            "lru_max_abs_error": err["lru"],
            "fifo_max_abs_error": err["fifo"],
            "residuals": [
                {"params": params, "observed": obs, "predicted": pred}
                for params, obs, pred, _ in residuals],
        },
        "planner": {
            "target_hit_rate": TARGET_HIT_RATE,
            "fit_wall_seconds": t_hfit,
            "solve_wall_seconds": t_solve,
            "verify_wall_seconds": t_verify,
            **summary,
        },
        "verification": summary["verification"],
    }, indent=1))

    # acceptance gates (the harness discards the artifact on raise)
    if err["lru"] > 0.02:
        raise AssertionError(
            f"LRU forward model missed the 2% band on the held-out "
            f"grid: max abs error {err['lru']:.4f}")
    fifo_band = 0.06 if quick else 0.02
    if err["fifo"] > fifo_band:
        raise AssertionError(
            f"FIFO interp model missed its {fifo_band:.0%} band: "
            f"max abs error {err['fifo']:.4f}")
    if not plan.verification["feasible"]:
        raise AssertionError(
            f"planner recommendation failed exact-replay verification: "
            f"{plan.verification}")
    if plan.savings_vs_uniform <= 0.15:
        raise AssertionError(
            f"planner did not beat uniform sizing meaningfully: "
            f"savings {plan.savings_vs_uniform:.1%}")
    if t_solve > 30.0:
        raise AssertionError(
            f"planner solve took {t_solve:.1f}s (> 30s budget)")

    if verbose:
        print(f"  forward: {len(residuals)} held-out cells, max abs "
              f"error lru {err['lru']:.4f} / fifo {err['fifo']:.4f}")
        print(f"  inverse: savings {plan.savings_vs_uniform:.1%} vs "
              f"uniform, verified hit "
              f"{plan.verification['achieved_hit_rate']:.4f} >= "
              f"{TARGET_HIT_RATE} in {plan.verification['attempts']} "
              f"attempt(s), solve {t_solve:.2f}s")

    return [
        ("plan.fit", t_fit * 1e6,
         f"cells={len(train_rep.cells)},"
         f"streams={train_rep.solver.get('fit_streams', 0)}"),
        ("plan.forward", t_held * 1e6,
         f"max_abs_err={max_abs_error:.4f}"),
        ("plan.solve", t_solve * 1e6,
         f"savings={plan.savings_vs_uniform:.1%},"
         f"feasible={plan.verification['feasible']}"),
    ]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.0f},{derived}")
