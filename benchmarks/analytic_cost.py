"""Analytic per-device cost model — exact trip counts for §Roofline.

XLA's ``cost_analysis()`` counts each ``while`` body **once**, so any
scanned model (ours scans layer groups, query blocks and SSD chunks)
under-reports FLOPs/bytes by ~the trip count.  This module reconstructs
the executed cost analytically from the config, shape and sharding rules
— the same formulas one writes on the napkin before hillclimbing — and is
validated against ``cost_analysis`` on unrolled small configs
(tests/test_roofline.py).

Conventions:
  * FLOPs: 2·M·N·K per matmul; blockwise-causal attention counts the full
    computed span (masked work is still executed — honesty over flattery);
  * training multiplier: fwd + remat-fwd + bwd = 4× layer matmul FLOPs
    (nothing_saveable policy), logits 3× (no remat at top level);
  * HBM bytes: parameter traffic (fwd/remat/bwd reads, grad+opt r/w),
    activation traffic per layer (c_act·T·d), attention score traffic,
    logits and decode-cache traffic;
  * collectives (baseline sharding): per-layer FSDP all-gathers over
    ``data``, per-layer TP all-reduces of activations over ``model``,
    grad reduce-scatter over ``data``, cross-pod grad all-reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (FFN_DENSE, FFN_MOE, FFN_NONE, MIXER_ATTN,
                                MIXER_ATTN_LOCAL, MIXER_SSM, MIXER_XATTN,
                                ArchConfig, InputShape)
from repro.models.moe import capacity

Q_BLOCK = 512
BF16 = 2
F32 = 4


@dataclasses.dataclass
class MeshInfo:
    pods: int
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.pods * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pods * self.data


def mesh_info(mesh_kind: str) -> MeshInfo:
    return MeshInfo(2, 16, 16) if mesh_kind == "multi" else \
        MeshInfo(1, 16, 16)


def _div(num: float, shard: int, enabled: bool) -> float:
    return num / shard if enabled else num


# ---------------------------------------------------------------------------
# Per-layer forward FLOPs (global across devices)
# ---------------------------------------------------------------------------
def attn_fwd_flops(cfg: ArchConfig, tokens: float, span: float) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.resolved_num_heads, cfg.num_kv_heads
    proj = 2.0 * tokens * d * hd * (2 * h + 2 * kv)
    attn = 2.0 * tokens * span * h * hd * 2
    return {"proj": proj, "attn": attn}


def mlp_fwd_flops(cfg: ArchConfig, tokens: float) -> float:
    return 6.0 * tokens * cfg.d_model * cfg.d_ff


def moe_fwd_flops(cfg: ArchConfig, batch: float, seq: float) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    tokens = batch * seq
    c = capacity(cfg, int(seq))
    router = 2.0 * tokens * d * e
    dispatch = 2.0 * batch * seq * e * c * d * 2     # dispatch + combine
    expert = 6.0 * batch * e * c * d * f
    return {"router": router, "dispatch": dispatch, "expert": expert}


def ssm_fwd_flops(cfg: ArchConfig, tokens: float, decode: bool) -> Dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h = cfg.ssm_heads
    q = cfg.ssm_chunk
    proj = 2.0 * tokens * d * (2 * di + 2 * n + h) + 2.0 * tokens * di * d
    conv = 2.0 * tokens * (di + 2 * n) * cfg.ssm_conv_width
    if decode:
        scan = 6.0 * tokens * di * n            # state update + readout
    else:
        scan = 2.0 * tokens * q * (n + di) + 4.0 * tokens * n * di
    return {"proj": proj, "conv": conv, "scan": scan}


def cell_cost(cfg: ArchConfig, shape: InputShape, mesh_kind: str,
              rules_table: Dict) -> Dict:
    """Per-device analytic cost for one dry-run cell."""
    mi = mesh_info(mesh_kind)
    b, s = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    seq_eff = 1 if decode else s
    tokens = float(b * seq_eff)
    t = rules_table

    heads_tp = mi.model if t.get("q_heads") else 1
    mlp_tp = mi.model if t.get("mlp") else 1
    moe_tp = mi.model if (t.get("experts") or t.get("expert_mlp")) else 1
    ssm_tp = mi.model if t.get("ssm_inner") else 1
    vocab_tp = mi.model if t.get("vocab") else 1
    dp = mi.dp if t.get("act_batch") else (
        mi.data if t.get("act_batch") is not None else 1)

    flops = 0.0
    layer_param_bytes = 0.0
    tp_allreduce_per_layer = 0.0   # activation bytes all-reduced over model
    pattern = cfg.pattern()
    g = cfg.num_groups()
    d = cfg.d_model
    t_loc = tokens / max(dp, 1)

    for spec in pattern:
        lf = 0.0
        if spec.mixer in (MIXER_ATTN, MIXER_ATTN_LOCAL, MIXER_XATTN):
            window = cfg.sliding_window if spec.mixer == MIXER_ATTN_LOCAL \
                else 0
            if spec.mixer == MIXER_XATTN:
                span = cfg.num_image_tokens
            elif decode:
                span = min(s, window) if window else s
            else:
                span = min(window + Q_BLOCK, s) if window else s
            af = attn_fwd_flops(cfg, tokens, span)
            lf += af["proj"] / heads_tp + af["attn"] / heads_tp
            hd = cfg.resolved_head_dim
            layer_param_bytes += d * hd * (2 * cfg.num_heads
                                           + 2 * cfg.num_kv_heads) * BF16
            tp_allreduce_per_layer += t_loc * d * BF16
        elif spec.mixer == MIXER_SSM:
            sf = ssm_fwd_flops(cfg, tokens, decode)
            lf += sum(sf.values()) / ssm_tp
            di, n = cfg.d_inner, cfg.ssm_state
            layer_param_bytes += (2 * d * di + 2 * d * n + d * cfg.ssm_heads
                                  + di * d) * BF16
            tp_allreduce_per_layer += t_loc * d * BF16
        if spec.ffn == FFN_DENSE:
            lf += mlp_fwd_flops(cfg, tokens) / mlp_tp
            layer_param_bytes += 3 * d * cfg.d_ff * BF16
            tp_allreduce_per_layer += t_loc * d * BF16
        elif spec.ffn == FFN_MOE:
            mf = moe_fwd_flops(cfg, float(b), float(seq_eff))
            lf += mf["router"] + (mf["dispatch"] + mf["expert"]) / moe_tp
            layer_param_bytes += (cfg.num_experts * 3 * d * cfg.d_ff) * BF16
            tp_allreduce_per_layer += t_loc * d * BF16
        flops += lf
    flops *= g                                            # all layers
    logits = 2.0 * tokens * d * cfg.vocab_size / vocab_tp
    fwd_mult, logit_mult = (4.0, 3.0) if shape.kind == "train" else (1.0, 1.0)
    total_flops = flops * fwd_mult + logits * logit_mult
    flops_per_dev = total_flops / (dp * 1.0)
    # note: TP divisors already applied per-op; dp divides the token dim.

    # ---- HBM bytes per device ------------------------------------------------
    params_local = cfg.param_count() * BF16 / (mi.data * mi.model)
    stack_params_local = layer_param_bytes * g / (mi.data * mi.model)
    c_act = 14.0 if shape.kind == "train" else 4.0
    act_bytes = g * len(pattern) * c_act * t_loc * d * BF16 / 1.0
    attn_traffic = 0.0
    cache_bytes = 0.0
    for spec in pattern:
        if spec.mixer in (MIXER_ATTN, MIXER_ATTN_LOCAL):
            window = cfg.sliding_window if spec.mixer == MIXER_ATTN_LOCAL \
                else 0
            span = (min(s, window) if window else s) if decode else \
                (min(window + Q_BLOCK, s) if window else s)
            hl = cfg.resolved_num_heads / heads_tp
            attn_traffic += 2.0 * t_loc * span * hl * F32 \
                * (3 if shape.kind == "train" else 1)
            if decode:
                cache_bytes += (b / max(dp, 1)) * span * cfg.num_kv_heads \
                    * cfg.resolved_head_dim * 2 * BF16 / \
                    (mi.model if t.get("cache_seq") else 1) * 2
        elif spec.mixer == MIXER_SSM and decode:
            cache_bytes += (b / max(dp, 1)) * cfg.ssm_heads * cfg.ssm_state \
                * cfg.ssm_headdim * F32 * 2 / ssm_tp
    attn_traffic *= g
    cache_bytes *= g
    logits_bytes = t_loc * cfg.vocab_size / vocab_tp * F32 * \
        (3 if shape.kind == "train" else 1)
    if shape.kind == "train":
        mo = 2 * BF16 if cfg.param_count() > 30e9 else 2 * F32
        weight_traffic = stack_params_local * 3 + \
            params_local * (2 + 2) + cfg.param_count() / \
            (mi.data * mi.model) * mo * 2
    else:
        weight_traffic = stack_params_local + params_local
    hbm_per_dev = weight_traffic + act_bytes + attn_traffic + \
        logits_bytes + cache_bytes

    # ---- collective bytes per device ----------------------------------------
    # train/prefill: weights are gathered per layer over `data` (FSDP);
    # decode: activations are tiny, so XLA keeps weights D-sharded and
    # all-reduces matmul *outputs* over `data` instead — no weight gathers.
    tp_ar = 2.0 * tp_allreduce_per_layer * g * \
        (mi.model - 1) / mi.model * (3 if shape.kind == "train" else 1)
    if not t.get("mlp") and not t.get("ssm_inner") and not t.get("experts") \
            and not t.get("expert_mlp"):
        tp_ar = 0.0
    grad_rs = 0.0
    pod_ar = 0.0
    if shape.kind == "decode":
        coll = 0.0
        data_ar = 2.0 * tp_allreduce_per_layer * g * \
            (mi.data - 1) / mi.data
    else:
        fsdp_gathers = 2.0 if shape.kind == "train" else 1.0
        coll = (layer_param_bytes * g / mi.model) * fsdp_gathers \
            * (mi.data - 1) / mi.data
        data_ar = 0.0
    if shape.kind == "train":
        grad_rs = (cfg.param_count() * BF16 / mi.model) * \
            (mi.data - 1) / mi.data
        if mi.pods > 1:
            pod_ar = 2.0 * cfg.param_count() * BF16 / \
                (mi.data * mi.model) * (mi.pods - 1) / mi.pods
    coll_per_dev = coll + tp_ar + grad_rs + pod_ar + data_ar

    return {
        "flops_per_dev": flops_per_dev,
        "hbm_bytes_per_dev": hbm_per_dev,
        "collective_bytes_per_dev": coll_per_dev,
        "breakdown": {
            "layer_flops": flops * fwd_mult / dp,
            "logit_flops": logits * logit_mult / dp,
            "weight_traffic": weight_traffic,
            "act_bytes": act_bytes,
            "attn_traffic": attn_traffic,
            "cache_bytes": cache_bytes,
            "fsdp_gather": coll,
            "tp_allreduce": tp_ar,
            "data_allreduce": data_ar,
            "grad_reduce_scatter": grad_rs,
            "pod_allreduce": pod_ar,
        },
    }
