"""Hierarchical cache tiers: L1/L2 split-sizing sweeps + collapsed fill.

The OSDF follow-on to the paper (arXiv:2007.01408) runs StashCache as a
tiered CDN: site-level L1 caches fill from regional L2 backbones, and
only backbone misses reach the origin.  This bench exercises the tiered
data plane end to end on two claims:

* **Split-sizing sweeps stay vectorized.** A ``SweepSpec`` over
  ``federation.tier1.cache_capacity × federation.tier2.cache_capacity ×
  eviction_policy × seed`` (100 cells; 8 quick) runs through the
  two-round batched executor: round one resolves every edge cache with
  the stack-distance / state-machine kernels, round two derives each
  backbone's reference stream from its children's miss streams (in
  global arrival order) and resolves the L2 caches with the *same*
  kernels.  Any cell falling back to the serial executor fails the
  bench; every cell must be byte-exact against a serial
  ``run_scenario`` replay, including the per-tier counters.

* **Tiered fill collapses origin egress.** A regional flash crowd (one
  region's edges hammering a small hot set) runs against the tiered
  federation and a parent-stripped flat twin.  With tiers, the first
  edge miss fills the regional backbone and sibling edges then fill
  cache-to-cache, so origin egress drops; the artifact records the
  reduction and the gate holds it above a floor.

**Artifact** ``artifacts/tiers.json`` (see docs/BENCHMARKS.md): sweep
inventory and wall-clocks for both executions, ``speedup``, the solver
telemetry (``tier_rounds`` — the two-round claim), the parity section
(per-tier keys included), and the ``egress`` section
(flat vs tiered origin bytes and the derived ``reduction``).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

from repro.core import (FederationSpec, ScenarioSpec, SweepSpec,
                        WorkloadSpec, run_scenario, run_sweep)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ("tiers.json",)

GB = 1000**3

PARITY_KEYS = ("bytes_moved", "cache_hits", "cache_misses",
               "origin_egress_bytes", "parent_fill_bytes", "evictions",
               "bytes_evicted", "tier_hits", "tier_misses",
               "tier_fill_bytes")


def tiered_base(n_requests: int) -> ScenarioSpec:
    return ScenarioSpec(
        name="tiers", engine="analytic",
        federation=FederationSpec.osdf(edges_per_region=2,
                                       workers_per_edge=2,
                                       l1_capacity=4 * GB,
                                       l2_capacity=24 * GB),
        workload=WorkloadSpec(kind="zipf", n_requests=n_requests,
                              working_set=12, duration=600.0, seed=11))


def tier_sweep_spec(quick: bool = False) -> SweepSpec:
    """The L1/L2 split-sizing sweep: 100 cells (8 quick) over
    ``tier1.cache_capacity × tier2.cache_capacity × policy × seed``,
    with capacities that bind (most cells churn at both levels)."""
    base = tiered_base(30 if quick else 60)
    if quick:
        axes = {
            "federation.tier1.cache_capacity": [1 * GB, 6 * GB],
            "federation.tier2.cache_capacity": [4 * GB, 24 * GB],
            "federation.eviction_policy": ["lru", "fifo"],
        }
    else:
        axes = {
            "federation.tier1.cache_capacity": [
                1 * GB, 2 * GB, 4 * GB, 6 * GB, 8 * GB],
            "federation.tier2.cache_capacity": [
                4 * GB, 8 * GB, 16 * GB, 24 * GB, 48 * GB],
            "federation.eviction_policy": ["lru", "fifo"],
            "workload.seed": [11, 12],
        }
    return SweepSpec(name="tiers", base=base, axes=axes)


def flash_crowd_pair(quick: bool = False):
    """The same regional flash crowd on the tiered federation and on a
    parent-stripped flat twin (identical sites, no hierarchy)."""
    n = 60 if quick else 120
    tiered = tiered_base(n)
    crowd = WorkloadSpec(
        kind="flash_crowd", n_requests=n, working_set=12,
        duration=600.0, seed=11,
        hot_sites=("us-east-edge0", "us-east-edge1"),
        crowd_factor=6.0, crowd_at=60.0, crowd_duration=120.0,
        n_objects=4, size=500_000_000)
    tiered = dataclasses.replace(tiered, name="crowd-tiered",
                                 workload=crowd)
    flat = dataclasses.replace(
        tiered, name="crowd-flat",
        federation=dataclasses.replace(
            tiered.federation,
            sites=[dataclasses.replace(s, parent=None)
                   for s in tiered.federation.sites]))
    return tiered, flat


def run(quick: bool = False, verbose: bool = False):
    spec = tier_sweep_spec(quick=quick)
    n_cells = len(spec)

    t0 = time.perf_counter()
    batched = run_sweep(spec, batched=True)
    t_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    serial = run_sweep(spec, batched=False, price_contention=False)
    t_serial = time.perf_counter() - t0
    speedup = t_serial / max(t_batched, 1e-9)

    mismatches = []
    for cb, cs in zip(batched.cells, serial.cells):
        for k in PARITY_KEYS:
            if cb.summary[k] != cs.summary[k]:
                mismatches.append({"params": cb.params, "key": k,
                                   "batched": cb.summary[k],
                                   "serial": cs.summary[k]})

    tiered_spec, flat_spec = flash_crowd_pair(quick=quick)
    tiered_sum = run_scenario(tiered_spec).summary()
    flat_sum = run_scenario(flat_spec).summary()
    flat_egress = flat_sum["origin_egress_bytes"]
    tiered_egress = tiered_sum["origin_egress_bytes"]
    reduction = 1.0 - tiered_egress / max(flat_egress, 1)

    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "tiers.json").write_text(json.dumps({
        "cells": n_cells,
        "quick": quick,
        "axes": {k: list(v) for k, v in spec.axes.items()},
        "batched": {
            "wall_seconds": t_batched,
            "batched_cells": batched.batched_cells,
            "serial_cells": batched.serial_cells,
            "solver": batched.solver,
        },
        "serial": {"wall_seconds": t_serial},
        "speedup": speedup,
        "parity": {"checked_cells": len(batched.cells),
                   "keys": list(PARITY_KEYS),
                   "mismatches": mismatches},
        "sample_cell": {"params": batched.cells[0].params,
                        "summary": batched.cells[0].summary},
        "egress": {
            "flat_origin_egress_bytes": flat_egress,
            "tiered_origin_egress_bytes": tiered_egress,
            "tiered_parent_fill_bytes": tiered_sum["parent_fill_bytes"],
            "tiered_tier_hits": tiered_sum["tier_hits"],
            "reduction": reduction,
        },
    }, indent=1))

    if mismatches:
        raise AssertionError(
            f"tiered batched/serial parity broke on {len(mismatches)} "
            f"cells: {mismatches[:3]}")
    if batched.serial_cells:
        raise AssertionError(
            f"{batched.serial_cells} tiered cells fell back to the "
            f"serial executor")
    if batched.solver.get("tier_rounds") != 2:
        raise AssertionError(
            f"expected the two-round executor, telemetry says "
            f"tier_rounds={batched.solver.get('tier_rounds')!r}")
    if reduction <= 0:
        raise AssertionError(
            f"tiered fill did not reduce origin egress: flat "
            f"{flat_egress} vs tiered {tiered_egress}")

    if verbose:
        print(f"  {n_cells} cells: batched {t_batched:.2f}s vs serial "
              f"{t_serial:.2f}s -> {speedup:.1f}x "
              f"(tier_rounds={batched.solver.get('tier_rounds')})")
        print(f"  flash crowd: origin egress {flat_egress / 1e9:.1f} GB "
              f"flat -> {tiered_egress / 1e9:.1f} GB tiered "
              f"({reduction:.1%} reduction)")

    return [
        ("tiers.batched", t_batched * 1e6,
         f"cells={n_cells},speedup={speedup:.1f}x"),
        ("tiers.serial", t_serial * 1e6, f"cells={n_cells}"),
        ("tiers.serial_cells", float(batched.serial_cells),
         f"cells={n_cells}"),
        ("tiers.parity", float(len(mismatches)),
         f"checked={len(batched.cells)},keys={len(PARITY_KEYS)}"),
        ("tiers.egress_reduction", reduction * 100.0,
         f"flat_gb={flat_egress / 1e9:.1f},"
         f"tiered_gb={tiered_egress / 1e9:.1f}"),
    ]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
