"""Paper Fig. 5 — Syracuse WAN offload after installing a cache.

Syracuse installed a StashCache cache specifically to cut outbound WAN
requests: the paper reports site WAN draw dropping from 14.3 GB/s to
1.6 GB/s (≈ 8.9×).  We replay a working-set workload against a
Syracuse-profile site twice on the fluid-flow simulator — direct-to-origin
(pre-install) vs through a freshly-installed local cache — and report the
WAN bytes/s before/after plus the offload ratio.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import (FluidFlowSim, PercentileSampler,
                        build_osg_federation, direct_download,
                        stash_download)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ('wan_offload.json',)


def run(workers: int = 16, files: int = 24, reuse: int = 9,
        verbose: bool = False):
    sampler = PercentileSampler(seed=7)
    sizes = [sampler.sample() for _ in range(files)]

    def replay(use_cache: bool):
        fed = build_osg_federation()
        origin = fed.origins[0]
        metas = [origin.put_object(f"/des/data/f{i}", s)
                 for i, s in enumerate(sizes)]
        sim = FluidFlowSim(fed.topology, fed.net)
        cache = fed.caches["syracuse/cache"]
        redirector = fed.redirectors.members[0].node.name
        # Each file is requested by `reuse` different workers (the reuse
        # that makes caching matter — paper working sets are shared).
        for r in range(reuse):
            for i, meta in enumerate(metas):
                w = (r * files + i) % workers
                wnode = fed.client("syracuse", w).node.name
                if use_cache:
                    sim.spawn(stash_download(
                        sim, wnode, cache, origin.node.name, redirector,
                        meta, fed.geoip.lookup_latency), at=0.1 * i)
                else:
                    sim.spawn(direct_download(
                        sim, wnode, origin.node.name, meta, streams=8),
                        at=0.1 * i)
        dur = sim.run()
        wan_bytes = sim.link_bytes.get("wan", 0.0)
        return wan_bytes, dur

    wan_before, t_before = replay(use_cache=False)
    wan_after, t_after = replay(use_cache=True)
    rate_before = wan_before / max(t_before, 1e-9) / 1e9
    rate_after = wan_after / max(t_after, 1e-9) / 1e9
    ratio = wan_before / max(wan_after, 1.0)
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "wan_offload.json").write_text(json.dumps({
        "wan_bytes_before": wan_before, "wan_bytes_after": wan_after,
        "wan_gbps_before": rate_before, "wan_gbps_after": rate_after,
        "offload_ratio": ratio,
        "paper": {"before_gbs": 14.3, "after_gbs": 1.6, "ratio": 8.9},
    }, indent=1))
    if verbose:
        print(f"  WAN before cache: {rate_before:6.2f} GB/s "
              f"({wan_before / 1e12:.2f} TB total)")
        print(f"  WAN after  cache: {rate_after:6.2f} GB/s "
              f"({wan_after / 1e12:.2f} TB total)")
        print(f"  offload ratio: {ratio:.1f}× (paper: ≈8.9×)")
    return [("wan_offload.replay", t_after * 1e6,
             f"ratio={ratio:.1f}x_paper=8.9x")]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
