"""fedlint gate profile: strict analysis counts + sanitizer smoke.

Runs the static analyzer over ``src/repro`` with the committed
``fedlint.toml`` baseline and the determinism sanitizer's quick
profile, then writes ``artifacts/fedlint.json`` so
``check_regression.py`` can pin the numbers like any perf metric:
``fedlint_violations`` at 0 (a new unsuppressed violation fails CI) and
``fedlint_suppressions`` at the reviewed baseline count (suppression
creep fails CI until the baseline is re-reviewed and re-baselined).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.analysis import run_analysis
from repro.analysis.sanitize import run_sanitizer

HERE = Path(__file__).parent
REPO = HERE.parent
ARTIFACTS = HERE / "artifacts"
ARTIFACT_FILES = ("fedlint.json",)


def run(quick: bool = True, verbose: bool = False):
    t0 = time.perf_counter()
    violations, entries = run_analysis(
        [REPO / "src" / "repro"], root=REPO,
        baseline=REPO / "fedlint.toml")
    lint_us = (time.perf_counter() - t0) * 1e6
    active = [v for v in violations if not v.suppressed]

    t0 = time.perf_counter()
    sanitizer_rows = run_sanitizer(quick=quick)
    sanitize_us = (time.perf_counter() - t0) * 1e6

    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "fedlint.json").write_text(json.dumps({
        "violations": len(active),
        "suppressed": len(violations) - len(active),
        "baseline_entries": len(entries),
        "active": [v.to_json() for v in active],
        "sanitizer": {
            "checks": len(sanitizer_rows),
            "rows": [{"check": c, "scenario": s, **stats}
                     for c, s, stats in sanitizer_rows],
        },
    }, indent=1))

    rows = [
        ("fedlint.strict", lint_us,
         f"violations={len(active)} suppressed="
         f"{len(violations) - len(active)}"),
        ("fedlint.sanitize", sanitize_us,
         f"checks={len(sanitizer_rows)}"),
    ]
    if verbose:
        for name, us, derived in rows:
            print(f"  {name}: {us / 1e6:.2f}s  {derived}")
    return rows
