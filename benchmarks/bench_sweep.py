"""Batched scenario sweeps: hundreds of ScenarioSpecs per solve.

The paper's claims hold across a *space* of federations — cache replica
counts, Zipf skews, outage rates — and this bench measures how fast we
can traverse that space.  One :class:`~repro.core.api.SweepSpec` (a
ScenarioSpec template × parameter axes) runs twice:

* **serial** — one :func:`~repro.core.api.run_scenario` per cell, a
  fresh federation and the full per-request client machinery each time
  (the pre-sweep baseline);
* **batched** — :func:`~repro.core.api.run_sweep`: pristine federations
  shared across same-spec cells, numpy first-occurrence hit/miss and
  egress accounting, and every cell's storm-counterfactual flow problem
  priced by the pow2-bucketed, vmapped max-min kernel
  (``repro.kernels.batched_maxmin``) in a handful of jitted calls.

Every cell's ``bytes_moved`` / ``cache_hits`` / ``cache_misses`` /
``origin_egress_bytes`` must be identical between the two executions —
the artifact records the parity check, and ``tests/test_sweep.py``
asserts it independently.

**Artifact** ``artifacts/sweep.json`` (see docs/BENCHMARKS.md): cell and
axis inventory, wall-clock for both executions, ``speedup`` (the CI
regression gate holds this ≥ 3× within tolerance), the batched solver
telemetry (``solve_calls`` per sweep — the "one jitted call prices a
column" claim), the parity section, and per-axis marginal tables built
by :class:`~repro.core.monitoring.SweepAggregator`.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (FederationSpec, ScenarioSpec, SweepAggregator,
                        SweepSpec, WorkloadSpec, run_sweep)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ("sweep.json",)

PARITY_KEYS = ("bytes_moved", "cache_hits", "cache_misses",
               "origin_egress_bytes")


def sweep_spec(quick: bool = False) -> SweepSpec:
    """The benchmark sweep: 216 cells (16 quick) over
    ``cache_replicas × zipf_a × seed × outage_rate``."""
    base = ScenarioSpec(
        name="sweep", engine="analytic",
        federation=FederationSpec.fleet(num_pods=2, hosts_per_pod=2),
        workload=WorkloadSpec(kind="zipf",
                              n_requests=30 if quick else 60,
                              working_set=16, duration=600.0))
    if quick:
        axes = {
            "federation.cache_replicas": [1, 2],
            "workload.zipf_a": [0.9, 1.3],
            "workload.seed": [0, 1],
            "outage_rate": [0.0, 0.5],
        }
    else:
        axes = {
            "federation.cache_replicas": [1, 2, 3],
            "workload.zipf_a": [0.7, 0.9, 1.1, 1.3, 1.5, 1.7],
            "workload.seed": [0, 1, 2, 3],
            "outage_rate": [0.0, 0.25, 0.5],
        }
    return SweepSpec(name="sweep", base=base, axes=axes)


def run(quick: bool = False, verbose: bool = False):
    spec = sweep_spec(quick=quick)
    n_cells = len(spec)

    t0 = time.perf_counter()
    batched = run_sweep(spec, batched=True)
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    serial = run_sweep(spec, batched=False, price_contention=False)
    t_serial = time.perf_counter() - t0

    mismatches = []
    for cb, cs in zip(batched.cells, serial.cells):
        for k in PARITY_KEYS:
            if cb.summary[k] != cs.summary[k]:
                mismatches.append({"params": cb.params, "key": k,
                                   "batched": cb.summary[k],
                                   "serial": cs.summary[k]})
    speedup = t_serial / max(t_batched, 1e-9)

    agg = SweepAggregator()
    for cell in batched.cells:
        agg.add(cell.params, cell.summary)
    marginals = {
        axis: [list(row) for row in agg.marginal(axis, "hit_rate")]
        for axis in spec.axes
    }

    sample = batched.cells[0]
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "sweep.json").write_text(json.dumps({
        "cells": n_cells,
        "quick": quick,
        "axes": {k: list(v) for k, v in spec.axes.items()},
        "batched": {
            "wall_seconds": t_batched,
            "batched_cells": batched.batched_cells,
            "serial_cells": batched.serial_cells,
            "solver": batched.solver,
        },
        "serial": {"wall_seconds": t_serial},
        "speedup": speedup,
        "parity": {"checked_cells": len(batched.cells),
                   "keys": list(PARITY_KEYS),
                   "mismatches": mismatches},
        "marginals_hit_rate": marginals,
        "sample_cell": {"params": sample.params,
                        "summary": sample.summary,
                        "pricing": sample.pricing},
    }, indent=1))

    if mismatches:
        raise AssertionError(
            f"batched/serial sweep parity broke on {len(mismatches)} "
            f"cells: {mismatches[:3]}")

    if verbose:
        print(f"  {n_cells} cells: batched {t_batched:.2f}s "
              f"(solve_calls={batched.solver.get('solve_calls')}) vs "
              f"serial {t_serial:.2f}s -> {speedup:.1f}x")
        for v, cells, mean, lo, hi in agg.marginal("workload.zipf_a",
                                                   "hit_rate"):
            print(f"  zipf_a={v}: hit_rate mean {mean:.3f} "
                  f"[{lo:.3f}, {hi:.3f}] over {cells} cells")

    solve_calls = int(batched.solver.get("solve_calls", 0))
    return [
        ("sweep.batched", t_batched * 1e6,
         f"cells={n_cells},speedup={speedup:.1f}x"),
        ("sweep.serial", t_serial * 1e6, f"cells={n_cells}"),
        ("sweep.solver_calls", float(solve_calls),
         f"priced_cells={batched.solver.get('priced_cells', 0)}"),
        ("sweep.parity", float(len(mismatches)),
         f"checked={len(batched.cells)},keys={len(PARITY_KEYS)}"),
    ]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
