"""Batched scenario sweeps: hundreds of ScenarioSpecs per solve.

The paper's claims hold across a *space* of federations — cache replica
counts, Zipf skews, outage rates — and this bench measures how fast we
can traverse that space.  One :class:`~repro.core.api.SweepSpec` (a
ScenarioSpec template × parameter axes) runs twice:

* **serial** — one :func:`~repro.core.api.run_scenario` per cell, a
  fresh federation and the full per-request client machinery each time
  (the pre-sweep baseline);
* **batched** — :func:`~repro.core.api.run_sweep`: pristine federations
  shared across same-spec cells, numpy first-occurrence hit/miss and
  egress accounting, and every cell's storm-counterfactual flow problem
  priced by the pow2-bucketed, vmapped max-min kernel
  (``repro.kernels.batched_maxmin``) in a handful of jitted calls.

Every cell's ``bytes_moved`` / ``cache_hits`` / ``cache_misses`` /
``origin_egress_bytes`` must be identical between the two executions —
the artifact records the parity check, and ``tests/test_sweep.py``
asserts it independently.

A second, **eviction-regime** profile sweeps the axes that used to be
serial-only — ``cache_capacity × eviction_policy {lru,fifo} ×
admission_max_fraction`` with working sets far beyond the smallest
capacities — through the stack-distance / cache-state-machine kernels
(:mod:`repro.kernels.stack_distance`).  Parity there additionally
covers ``evictions`` / ``bytes_evicted`` / ``admission_rejects``, and
any cell falling back to the serial executor fails the bench.

**Artifact** ``artifacts/sweep.json`` (see docs/BENCHMARKS.md): cell and
axis inventory, wall-clock for both executions, ``speedup`` (the CI
regression gate holds this ≥ 3× within tolerance), the batched solver
telemetry (``solve_calls`` per sweep — the "one jitted call prices a
column" claim), the parity section, per-axis marginal tables built
by :class:`~repro.core.monitoring.SweepAggregator`, and the
``eviction`` section (same schema + ``total_evictions`` and per-policy
marginals; its ``speedup`` is gated ≥ 3× too).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (FederationSpec, ScenarioSpec, SweepAggregator,
                        SweepSpec, WorkloadSpec, run_sweep)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ("sweep.json",)

PARITY_KEYS = ("bytes_moved", "cache_hits", "cache_misses",
               "origin_egress_bytes")
EVICTION_PARITY_KEYS = PARITY_KEYS + ("evictions", "bytes_evicted",
                                      "admission_rejects")


def sweep_spec(quick: bool = False) -> SweepSpec:
    """The benchmark sweep: 216 cells (16 quick) over
    ``cache_replicas × zipf_a × seed × outage_rate``."""
    base = ScenarioSpec(
        name="sweep", engine="analytic",
        federation=FederationSpec.fleet(num_pods=2, hosts_per_pod=2),
        workload=WorkloadSpec(kind="zipf",
                              n_requests=30 if quick else 60,
                              working_set=16, duration=600.0))
    if quick:
        axes = {
            "federation.cache_replicas": [1, 2],
            "workload.zipf_a": [0.9, 1.3],
            "workload.seed": [0, 1],
            "outage_rate": [0.0, 0.5],
        }
    else:
        axes = {
            "federation.cache_replicas": [1, 2, 3],
            "workload.zipf_a": [0.7, 0.9, 1.1, 1.3, 1.5, 1.7],
            "workload.seed": [0, 1, 2, 3],
            "outage_rate": [0.0, 0.25, 0.5],
        }
    return SweepSpec(name="sweep", base=base, axes=axes)


def eviction_sweep_spec(quick: bool = False) -> SweepSpec:
    """The eviction-regime profile: capacity × eviction policy ×
    size-aware admission, with working sets far beyond the smallest
    capacities so most cells churn — the axes that were serial-only
    before the stack-distance / state-machine kernels landed."""
    base = ScenarioSpec(
        name="evict", engine="analytic",
        federation=FederationSpec.fleet(num_pods=2, hosts_per_pod=2),
        workload=WorkloadSpec(kind="zipf",
                              n_requests=30 if quick else 60,
                              working_set=16, duration=600.0))
    if quick:
        axes = {
            "federation.cache_capacity": [3e8, 32e12],
            "federation.eviction_policy": ["lru", "fifo"],
            "federation.admission_max_fraction": [1.0, 0.3],
        }
    else:
        axes = {
            "federation.cache_capacity": [2e8, 4e8, 8e8, 1.6e9, 3.2e9,
                                          32e12],
            "federation.eviction_policy": ["lru", "fifo"],
            "federation.admission_max_fraction": [1.0, 0.5, 0.25],
            "workload.seed": [0, 1, 2],
        }
    return SweepSpec(name="evict", base=base, axes=axes)


def _run_both(spec: SweepSpec, parity_keys):
    """One sweep, batched then serial, with per-cell parity."""
    t0 = time.perf_counter()
    batched = run_sweep(spec, batched=True)
    t_batched = time.perf_counter() - t0
    t0 = time.perf_counter()
    serial = run_sweep(spec, batched=False, price_contention=False)
    t_serial = time.perf_counter() - t0
    mismatches = []
    for cb, cs in zip(batched.cells, serial.cells):
        for k in parity_keys:
            if cb.summary[k] != cs.summary[k]:
                mismatches.append({"params": cb.params, "key": k,
                                   "batched": cb.summary[k],
                                   "serial": cs.summary[k]})
    speedup = t_serial / max(t_batched, 1e-9)
    return batched, t_batched, t_serial, speedup, mismatches


def run(quick: bool = False, verbose: bool = False):
    spec = sweep_spec(quick=quick)
    n_cells = len(spec)
    (batched, t_batched, t_serial,
     speedup, mismatches) = _run_both(spec, PARITY_KEYS)

    espec = eviction_sweep_spec(quick=quick)
    (ebatched, et_batched, et_serial,
     espeedup, emismatches) = _run_both(espec, EVICTION_PARITY_KEYS)
    total_evictions = sum(c.summary["evictions"] for c in ebatched.cells)

    agg = SweepAggregator()
    for cell in batched.cells:
        agg.add(cell.params, cell.summary)
    marginals = {
        axis: [list(row) for row in agg.marginal(axis, "hit_rate")]
        for axis in spec.axes
    }
    eagg = SweepAggregator()
    for cell in ebatched.cells:
        eagg.add(cell.params, cell.summary)

    sample = batched.cells[0]
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "sweep.json").write_text(json.dumps({
        "cells": n_cells,
        "quick": quick,
        "axes": {k: list(v) for k, v in spec.axes.items()},
        "batched": {
            "wall_seconds": t_batched,
            "batched_cells": batched.batched_cells,
            "serial_cells": batched.serial_cells,
            "solver": batched.solver,
        },
        "serial": {"wall_seconds": t_serial},
        "speedup": speedup,
        "parity": {"checked_cells": len(batched.cells),
                   "keys": list(PARITY_KEYS),
                   "mismatches": mismatches},
        "marginals_hit_rate": marginals,
        "sample_cell": {"params": sample.params,
                        "summary": sample.summary,
                        "pricing": sample.pricing},
        "eviction": {
            "cells": len(espec),
            "axes": {k: list(v) for k, v in espec.axes.items()},
            "batched": {
                "wall_seconds": et_batched,
                "batched_cells": ebatched.batched_cells,
                "serial_cells": ebatched.serial_cells,
                "solver": ebatched.solver,
            },
            "serial": {"wall_seconds": et_serial},
            "speedup": espeedup,
            "total_evictions": total_evictions,
            "parity": {"checked_cells": len(ebatched.cells),
                       "keys": list(EVICTION_PARITY_KEYS),
                       "mismatches": emismatches},
            "policy_marginals": [list(r) for r in eagg.policy_marginals()],
        },
    }, indent=1))

    if mismatches or emismatches:
        bad = mismatches + emismatches
        raise AssertionError(
            f"batched/serial sweep parity broke on {len(bad)} "
            f"cells: {bad[:3]}")
    if ebatched.serial_cells:
        raise AssertionError(
            f"{ebatched.serial_cells} eviction-regime cells fell back to "
            f"the serial executor")

    if verbose:
        print(f"  {n_cells} cells: batched {t_batched:.2f}s "
              f"(solve_calls={batched.solver.get('solve_calls')}) vs "
              f"serial {t_serial:.2f}s -> {speedup:.1f}x")
        for v, cells, mean, lo, hi in agg.marginal("workload.zipf_a",
                                                   "hit_rate"):
            print(f"  zipf_a={v}: hit_rate mean {mean:.3f} "
                  f"[{lo:.3f}, {hi:.3f}] over {cells} cells")
        print(f"  eviction regime, {len(espec)} cells: batched "
              f"{et_batched:.2f}s vs serial {et_serial:.2f}s -> "
              f"{espeedup:.1f}x ({total_evictions} evictions)")
        for row in eagg.policy_marginals():
            print(f"  policy={row[0]}: hit_rate {row[2]:.3f}, "
                  f"evictions {row[3]:.0f}, rejects {row[5]:.0f} "
                  f"over {row[1]} cells")

    solve_calls = int(batched.solver.get("solve_calls", 0))
    return [
        ("sweep.batched", t_batched * 1e6,
         f"cells={n_cells},speedup={speedup:.1f}x"),
        ("sweep.serial", t_serial * 1e6, f"cells={n_cells}"),
        ("sweep.solver_calls", float(solve_calls),
         f"priced_cells={batched.solver.get('priced_cells', 0)}"),
        ("sweep.parity", float(len(mismatches)),
         f"checked={len(batched.cells)},keys={len(PARITY_KEYS)}"),
        ("sweep.eviction_batched", et_batched * 1e6,
         f"cells={len(espec)},speedup={espeedup:.1f}x,"
         f"evictions={total_evictions}"),
        ("sweep.eviction_serial_cells", float(ebatched.serial_cells),
         f"cells={len(espec)}"),
        ("sweep.eviction_parity", float(len(emismatches)),
         f"checked={len(ebatched.cells)},keys={len(EVICTION_PARITY_KEYS)}"),
    ]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
