"""Fleet benchmark — checkpoint restart storm through pod caches.

The TPU-fleet translation of the paper's core value proposition: after a
preemption, N hosts per pod simultaneously pull the same checkpoint.
Direct-to-origin, the storage fabric sees N× the checkpoint size; through
the pod-cache federation it sees ~1× per pod (collapsed forwarding — the
in-flight pull is shared), and the storm drains at ICI speed.

Reported: origin egress and storm completion time, with/without caches.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import (FluidFlowSim, build_fleet_federation,
                        direct_download, stash_download)

ARTIFACTS = Path(__file__).parent / "artifacts"


def run(pods: int = 2, hosts: int = 64, ckpt_gb: float = 8.0,
        verbose: bool = False):
    size = int(ckpt_gb * 1e9)

    def storm(use_cache: bool):
        fed = build_fleet_federation(num_pods=pods, hosts_per_pod=hosts)
        origin = fed.origins[0]
        meta = origin.put_object("/ckpt/run1/step_00001000/params.npy", size)
        sim = FluidFlowSim(fed.topology, fed.net)
        redirector = fed.redirectors.members[0].node.name
        for p in range(pods):
            cache = fed.caches[f"pod{p}/cache"]
            for h in range(hosts):
                wnode = fed.client(f"pod{p}", h).node.name
                if use_cache:
                    sim.spawn(stash_download(
                        sim, wnode, cache, origin.node.name, redirector,
                        meta, fed.geoip.lookup_latency))
                else:
                    sim.spawn(direct_download(
                        sim, wnode, origin.node.name, meta, streams=8))
        dur = sim.run()
        origin_egress = (sum(c.stats.bytes_from_origin
                             for c in fed.caches.values())
                         if use_cache else size * pods * hosts)
        return dur, origin_egress

    t_direct, egress_direct = storm(False)
    t_cached, egress_cached = storm(True)
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "restart_storm.json").write_text(json.dumps({
        "pods": pods, "hosts_per_pod": hosts, "ckpt_bytes": size,
        "direct": {"seconds": t_direct, "origin_egress": egress_direct},
        "cached": {"seconds": t_cached, "origin_egress": egress_cached},
        "egress_reduction": egress_direct / max(egress_cached, 1),
        "speedup": t_direct / max(t_cached, 1e-9)}, indent=1))
    if verbose:
        print(f"  direct: {t_direct:8.1f}s, origin egress "
              f"{egress_direct / 1e12:.2f} TB")
        print(f"  cached: {t_cached:8.1f}s, origin egress "
              f"{egress_cached / 1e9:.2f} GB")
        print(f"  egress reduction {egress_direct / max(egress_cached, 1):.0f}×, "
              f"storm speedup {t_direct / t_cached:.1f}×")
    return [("restart_storm.cached", t_cached * 1e6,
             f"egress_reduction={egress_direct / max(egress_cached, 1):.0f}x"),
            ("restart_storm.direct", t_direct * 1e6,
             f"hosts={pods * hosts}")]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
