"""Fleet benchmark — checkpoint restart storm through pod caches.

The TPU-fleet translation of the paper's core value proposition: after a
preemption, N hosts per pod simultaneously pull the same checkpoint.
Direct-to-origin, the storage fabric sees N× the checkpoint size; through
the pod-cache federation it sees ~1× per pod (collapsed forwarding — the
in-flight pull is shared), and the storm drains at ICI speed.

Both arms are one :class:`ScenarioSpec` executed on the simulated engine
with a different fetch ``method`` (``stash`` vs ``direct``); a third,
quick spec runs on *both* engines and lands in the artifact's ``parity``
section — the CI smoke asserts the two engines report the same
``FetchResult`` schema and identical bytes/hit/miss counts.

Reported: origin egress and storm completion time, with/without caches.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from repro.core import (FederationSpec, FetchResult, ScenarioSpec,
                        WorkloadSpec, run_scenario)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ('restart_storm.json',)

CKPT_PATH = "/ckpt/run1/step_00001000/params.npy"


def _storm_spec(pods: int, hosts: int, size: int, method: str,
                engine: str = "sim") -> ScenarioSpec:
    return ScenarioSpec(
        name=f"restart_storm/{method}",
        federation=FederationSpec.fleet(num_pods=pods, hosts_per_pod=hosts),
        workload=WorkloadSpec(kind="storm", path=CKPT_PATH, size=size,
                              workers_per_site=hosts),
        method=method, engine=engine)


def _parity(pods: int = 1, hosts: int = 4, size: int = int(5e8)) -> dict:
    """The same quick storm spec on both engines: the shared FetchResult
    schema plus the byte/hit/miss aggregates that must agree."""
    out: dict = {"fetch_result_fields":
                 sorted(f.name for f in dataclasses.fields(FetchResult))}
    for engine in ("analytic", "sim"):
        rep = run_scenario(_storm_spec(pods, hosts, size, "stash", engine))
        s = rep.summary()
        out[engine] = {
            "sample_result": dataclasses.asdict(rep.results[0]),
            "bytes_moved": s["bytes_moved"],
            "cache_hits": s["cache_hits"],
            "cache_misses": s["cache_misses"],
            "origin_egress_bytes": s["origin_egress_bytes"],
        }
    return out


def run(pods: int = 2, hosts: int = 64, ckpt_gb: float = 8.0,
        verbose: bool = False):
    size = int(ckpt_gb * 1e9)

    def storm(method: str):
        rep = run_scenario(_storm_spec(pods, hosts, size, method))
        return rep.sim_seconds, rep.origin_egress_bytes

    t_direct, egress_direct = storm("direct")
    t_cached, egress_cached = storm("stash")
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "restart_storm.json").write_text(json.dumps({
        "pods": pods, "hosts_per_pod": hosts, "ckpt_bytes": size,
        "direct": {"seconds": t_direct, "origin_egress": egress_direct},
        "cached": {"seconds": t_cached, "origin_egress": egress_cached},
        "egress_reduction": egress_direct / max(egress_cached, 1),
        "speedup": t_direct / max(t_cached, 1e-9),
        "parity": _parity()}, indent=1))
    if verbose:
        print(f"  direct: {t_direct:8.1f}s, origin egress "
              f"{egress_direct / 1e12:.2f} TB")
        print(f"  cached: {t_cached:8.1f}s, origin egress "
              f"{egress_cached / 1e9:.2f} GB")
        print(f"  egress reduction {egress_direct / max(egress_cached, 1):.0f}×, "
              f"storm speedup {t_direct / t_cached:.1f}×")
    return [("restart_storm.cached", t_cached * 1e6,
             f"egress_reduction={egress_direct / max(egress_cached, 1):.0f}x"),
            ("restart_storm.direct", t_direct * 1e6,
             f"hosts={pods * hosts}")]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
