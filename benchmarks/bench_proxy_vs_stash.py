"""Paper §4.1/§5 — StashCache vs distributed HTTP proxies (Table 3,
Figs 6–8).

Protocol follows the paper's DAGMan workflow: for each of the five OSG
test sites (one at a time — no competition at the origin), each file from
the Table-2 percentile set (+ the 10 GB probe) is downloaded four times:
  1. curl via the site HTTP proxy   (cold — verified cache miss)
  2. curl via the site HTTP proxy   (warm)
  3. stashcp via the nearest cache  (cold)
  4. stashcp via the nearest cache  (warm)
each (site, file) being one *sequential* :class:`ScenarioSpec` — four
:class:`FetchRequest`s chained on the simulated engine against a fresh
OSG federation, so cache state carries cold → warm but downloads never
compete.  The routed client chain (GeoIP ranking → ring → failover)
replaces the old bench's hand-picked nearest cache.

Outputs per (site, file): download speeds (Figs 6–8) and the Table-3
percent time difference for the 2.3 GB and 10 GB files, compared against
the paper's measured values (sign agreement asserted in tests).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from repro.core import (FederationSpec, FetchRequest, PAPER_TABLE3,
                        ScenarioSpec, evaluation_fileset, run_scenario)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ('proxy_vs_stash.json',)

PHASES = ("proxy_cold", "proxy_warm", "stash_cold", "stash_warm")


def run_site(site: str) -> List[dict]:
    """The 4-download protocol for every evaluation file at one site."""
    rows = []
    for path, size in evaluation_fileset():
        spec = ScenarioSpec(
            name=f"proxy_vs_stash/{site}",
            federation=FederationSpec.osg(),   # fresh caches per file set
            workload=[FetchRequest(path, site=site,
                                   method=phase.split("_")[0], size=size)
                      for phase in PHASES],
            sequential=True, engine="sim")
        rep = run_scenario(spec)
        row = {"site": site, "path": path, "size": size}
        for phase, r in zip(PHASES, rep.results):
            row[f"{phase}_s"] = r.seconds
            row[f"{phase}_mbps"] = size / r.seconds / 1e6
            row[f"{phase}_hit"] = r.cache_hit
        rows.append(row)
    return rows


def table3(rows: List[dict]) -> Dict[str, Dict[str, float]]:
    """Percent time difference StashCache vs HTTP proxy (negative =
    StashCache faster), for the 95th-pct (2.3 GB) and 10 GB files."""
    out: Dict[str, Dict[str, float]] = {}
    for row in rows:
        label = None
        if "p95" in row["path"]:
            label = "2.3GB"
        elif "10gb" in row["path"]:
            label = "10GB"
        if label is None:
            continue
        t_proxy = (row["proxy_cold_s"] + row["proxy_warm_s"]) / 2
        t_stash = (row["stash_cold_s"] + row["stash_warm_s"]) / 2
        out.setdefault(row["site"], {})[label] = \
            100.0 * (t_stash - t_proxy) / t_proxy
    return out


def run(verbose: bool = False):
    sites = list(PAPER_TABLE3)
    all_rows = []
    for site in sites:                      # sites run one at a time (§4.1)
        all_rows.extend(run_site(site))
    t3 = table3(all_rows)
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "proxy_vs_stash.json").write_text(
        json.dumps({"rows": all_rows, "table3": t3,
                    "paper_table3": PAPER_TABLE3}, indent=1))
    results = []
    sign_matches = 0
    cells = 0
    for site, cols in t3.items():
        for label, ours in cols.items():
            paper = PAPER_TABLE3[site][label]
            cells += 1
            if (ours < 0) == (paper < 0):
                sign_matches += 1
            if verbose:
                print(f"  {site:12s} {label:6s} ours={ours:+8.1f}% "
                      f"paper={paper:+8.1f}%")
    small = [r for r in all_rows if r["size"] < 1e6]
    small_proxy_wins = sum(
        1 for r in small if r["proxy_warm_s"] < r["stash_warm_s"])
    mean_t = sum(r["stash_cold_s"] for r in all_rows) / len(all_rows)
    results.append(("proxy_vs_stash.protocol", mean_t * 1e6,
                    f"sites={len(sites)}"))
    results.append(("proxy_vs_stash.table3_sign_agreement",
                    0.0, f"{sign_matches}/{cells}"))
    results.append(("proxy_vs_stash.small_file_proxy_wins", 0.0,
                    f"{small_proxy_wins}/{len(small)}"))
    return results


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
