"""Fleet benchmark — LM training/serving traffic through the federation.

The api_redesign payoff measured end-to-end: the same model-derived
``WorkloadSpec``s (``from_model_config``) that the loader/checkpointer/
serve engine produce, executed as declarative ``ScenarioSpec``s on both
engines.  The headline question: **which federation serves a 1000-pod
training restart fastest, and at what origin-egress cost?**

Arms:
  * **restart storm** — every pod re-fetches a 33B checkpoint's manifest
    plus its model-parallel rank's shards (``kind="restart"``), cached
    (``stash``) vs cache-bypass (``direct``); reported as storm
    completion time, origin egress, and the egress-reduction ratio the
    regression gate holds;
  * **engine parity** — the same quick restart spec on the analytic and
    simulated planes must agree byte-for-byte (the redesign's core
    invariant: one workload, two interchangeable engines);
  * **federation shootout** — the identical restart traffic against the
    flat fleet topology vs the hierarchical OSDF topology;
  * **serve / dataloader** (quick) — Zipf shard serving and striped
    dataset reads, the other two model-traffic kinds, so the artifact
    schema carries all three.

Profiles: ``run(quick=True)`` is the CI smoke (2 pods × 16 hosts);
``run()`` is the full 8 × 125 = 1000-pod storm from the real
deepseek-coder-33b byte total (~67 GB bf16) used by the weekly job.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from repro.configs import get_config
from repro.core import (FederationSpec, FetchResult, ScenarioSpec,
                        WorkloadSpec, run_scenario)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ("train_traffic.json",)

GB = 1 << 30
PARITY_KEYS = ("bytes_moved", "cache_hits", "cache_misses",
               "origin_egress")


def _summary(rep) -> dict:
    return {"seconds": rep.sim_seconds,
            "bytes_moved": rep.bytes_moved,
            "cache_hits": rep.cache_hits,
            "cache_misses": rep.cache_misses,
            "origin_egress": rep.origin_egress_bytes}


def restart_spec(cfg, pods: int, hosts: int, tp_degree: int,
                 method: str = "stash", engine: str = "sim",
                 federation: FederationSpec = None) -> ScenarioSpec:
    """The 1000-pod acceptance scenario (or its quick twin): a restart
    workload derived from the model config's exact byte total."""
    ws = WorkloadSpec.from_model_config(
        cfg, kind="restart", shard_bytes=GB, workers_per_site=hosts,
        tp_degree=tp_degree, jitter=5.0)
    fed = federation or FederationSpec.fleet(num_pods=pods,
                                             hosts_per_pod=hosts)
    return ScenarioSpec(name=f"train_traffic/restart/{method}/{engine}",
                        federation=fed, workload=ws, method=method,
                        engine=engine)


def _parity(cfg, pods: int, hosts: int, tp_degree: int) -> dict:
    """The same restart spec on both engines: shared FetchResult schema
    plus the aggregates that must agree exactly."""
    out: dict = {"fetch_result_fields":
                 sorted(f.name for f in dataclasses.fields(FetchResult)),
                 "mismatches": []}
    for engine in ("analytic", "sim"):
        rep = run_scenario(restart_spec(cfg, pods, hosts, tp_degree,
                                        engine=engine))
        out[engine] = dict(_summary(rep),
                           sample_result=dataclasses.asdict(rep.results[0]))
    for key in PARITY_KEYS:
        if out["analytic"][key] != out["sim"][key]:
            out["mismatches"].append(
                {"key": key, "analytic": out["analytic"][key],
                 "sim": out["sim"][key]})
    return out


def _quick_kinds(cfg, pods: int, hosts: int) -> dict:
    """The other two model-traffic kinds, quick scale, both engines."""
    out: dict = {}
    serve = WorkloadSpec.from_model_config(
        cfg, kind="serve", shard_bytes=GB, n_requests=4 * pods * hosts,
        duration=600.0, workers_per_site=hosts)
    loader = WorkloadSpec(
        kind="dataloader", path="/datasets/train", n_objects=32,
        total_bytes=32 * (256 << 20), workers_per_site=hosts,
        step_gap=1.0)
    for label, ws in (("serve", serve), ("dataloader", loader)):
        out[label] = {"mismatches": []}
        for engine in ("analytic", "sim"):
            rep = run_scenario(ScenarioSpec(
                name=f"train_traffic/{label}/{engine}",
                federation=FederationSpec.fleet(num_pods=pods,
                                                hosts_per_pod=hosts),
                workload=ws, engine=engine))
            out[label][engine] = _summary(rep)
        for key in PARITY_KEYS:
            if out[label]["analytic"][key] != out[label]["sim"][key]:
                out[label]["mismatches"].append(key)
    return out


def run(quick: bool = False, verbose: bool = False):
    cfg = get_config("deepseek-coder-33b", smoke=False)
    pods, hosts, tp = (2, 16, 8) if quick else (8, 125, 25)

    def storm(method: str):
        return run_scenario(restart_spec(cfg, pods, hosts, tp,
                                         method=method))

    rep_cached = storm("stash")
    rep_direct = storm("direct")
    egress_reduction = (rep_direct.origin_egress_bytes
                        / max(rep_cached.origin_egress_bytes, 1))
    speedup = rep_direct.sim_seconds / max(rep_cached.sim_seconds, 1e-9)

    # Federation shootout: identical restart traffic, two topologies.
    feds = {
        "fleet": FederationSpec.fleet(num_pods=pods, hosts_per_pod=hosts),
        "osdf": FederationSpec.osdf(
            regions=tuple(f"region{i}" for i in range(pods)),
            edges_per_region=2, workers_per_edge=max(1, hosts // 2)),
    }
    shootout = {}
    for name, fspec in feds.items():
        rep = run_scenario(restart_spec(cfg, pods, hosts, tp,
                                        federation=fspec))
        shootout[name] = _summary(rep)
    winner = min(shootout, key=lambda n: shootout[n]["seconds"])

    parity = _parity(cfg, *((2, 16, 8) if quick else (pods, hosts, tp)))
    kinds = _quick_kinds(cfg, 2, 8)

    ws = WorkloadSpec.from_model_config(cfg, kind="restart",
                                        shard_bytes=GB,
                                        workers_per_site=hosts,
                                        tp_degree=tp)
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "train_traffic.json").write_text(json.dumps({
        "profile": "quick" if quick else "full",
        "model": cfg.name,
        "checkpoint_bytes": ws.total_bytes,
        "n_shards": ws.n_objects,
        "pods": pods * hosts,
        "sites": pods,
        "workers_per_site": hosts,
        "tp_degree": tp,
        "restart": {
            "cached": _summary(rep_cached),
            "direct": _summary(rep_direct),
            "egress_reduction": egress_reduction,
            "speedup": speedup,
        },
        "federations": dict(shootout, winner=winner),
        "parity": parity,
        "kinds": kinds}, indent=1))
    if verbose:
        print(f"  {cfg.name}: {ws.total_bytes / 1e9:.1f} GB over "
              f"{ws.n_objects} shards, {pods * hosts} pods (tp={tp})")
        print(f"  cached: {rep_cached.sim_seconds:8.1f}s, origin egress "
              f"{rep_cached.origin_egress_bytes / 1e9:.1f} GB")
        print(f"  direct: {rep_direct.sim_seconds:8.1f}s, origin egress "
              f"{rep_direct.origin_egress_bytes / 1e12:.2f} TB")
        print(f"  egress reduction {egress_reduction:.0f}x, "
              f"storm speedup {speedup:.1f}x")
        print(f"  shootout: {winner} wins "
              f"({shootout[winner]['seconds']:.1f}s)")
        print(f"  parity mismatches: {len(parity['mismatches'])}")
    return [("train_traffic.restart_cached", rep_cached.sim_seconds * 1e6,
             f"egress_reduction={egress_reduction:.0f}x"),
            ("train_traffic.restart_direct", rep_direct.sim_seconds * 1e6,
             f"pods={pods * hosts}"),
            ("train_traffic.parity", float(len(parity["mismatches"])),
             f"engines_agree_on={','.join(PARITY_KEYS)}"),
            ("train_traffic.shootout",
             shootout[winner]["seconds"] * 1e6, f"winner={winner}")]


if __name__ == "__main__":
    import sys
    for name, us, derived in run(quick="--quick" in sys.argv,
                                 verbose=True):
        print(f"{name},{us:.1f},{derived}")
