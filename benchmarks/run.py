"""Benchmark harness — one module per paper table/figure + fleet benches.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_proxy_vs_stash   paper Table 3 + Figs 6–8 (4-download protocol)
  bench_wan_offload      paper Fig. 5 (Syracuse WAN collapse)
  bench_utilization      paper Table 1 + Fig. 4 (monitoring pipeline)
  bench_restart_storm    fleet: checkpoint fan-in through pod caches
  bench_fleet_scale      fleet: 1000-site storm, churn, eviction policies
  bench_outage_storm     fleet: simulator-native clients under outage storms
  bench_loader           fleet: federated training-data path
  bench_micro            federation hot-path micro-benchmarks
  bench_roofline         §Roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import sys
import traceback


def main() -> int:
    from . import (bench_fleet_scale, bench_loader, bench_micro,
                   bench_outage_storm, bench_proxy_vs_stash,
                   bench_restart_storm, bench_roofline, bench_utilization,
                   bench_wan_offload)
    modules = [bench_proxy_vs_stash, bench_wan_offload, bench_utilization,
               bench_restart_storm, bench_fleet_scale, bench_outage_storm,
               bench_loader, bench_micro, bench_roofline]
    print("name,us_per_call,derived")
    failed = 0
    for mod in modules:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{mod.__name__},ERROR,{type(e).__name__}:{e}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
