"""Benchmark harness — one module per paper table/figure + fleet benches.

Bench modules are auto-discovered: every ``benchmarks/bench_*.py`` that
exposes ``run() -> [(name, us_per_call, derived), ...]`` is picked up
(the old hard-coded import list silently skipped new benches).  Prints
``name,us_per_call,derived`` CSV rows.

A module that raises makes the harness exit non-zero *and* discards the
artifacts that module owns (its ``ARTIFACT_FILES`` names under
``benchmarks/artifacts/``): a failing bench used to leave whatever
artifact a previous run wrote — or a partial write — on disk, and the
CI regression gate would happily diff stale numbers.  No artifact is
better than a wrong one.

  python -m benchmarks.run                      # every bench
  python -m benchmarks.run --list               # discovered modules
  python -m benchmarks.run --only outage_storm  # substring/name select
  python -m benchmarks.run --only bench_micro --only bench_roofline

  bench_proxy_vs_stash   paper Table 3 + Figs 6–8 (4-download protocol)
  bench_wan_offload      paper Fig. 5 (Syracuse WAN collapse)
  bench_utilization      paper Table 1 + Fig. 4 (monitoring pipeline)
  bench_restart_storm    fleet: checkpoint fan-in through pod caches
  bench_fleet_scale      fleet: 1000-site storm, churn, eviction policies
  bench_outage_storm     fleet: simulator-native clients under outage storms
  bench_sweep            fleet: batched 216-cell scenario sweep vs serial
  bench_loader           fleet: federated training-data path
  bench_micro            federation hot-path micro-benchmarks
  bench_roofline         §Roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import importlib
import pkgutil
import sys
import traceback
from pathlib import Path
from typing import Dict, List, Optional


def discover() -> Dict[str, object]:
    """Import every ``bench_*`` module in this package, sorted by name."""
    import benchmarks
    names = sorted(m.name for m in pkgutil.iter_modules(benchmarks.__path__)
                   if m.name.startswith("bench_"))
    return {n: importlib.import_module(f"benchmarks.{n}") for n in names}


def discard_artifacts(mod: object) -> List[str]:
    """Remove the artifacts a failed bench owns so no stale (or
    truncated) JSON survives for downstream tooling to mistake for a
    fresh result.  Modules declare ownership via ``ARTIFACT_FILES``."""
    artifacts = Path(__file__).parent / "artifacts"
    removed: List[str] = []
    for name in getattr(mod, "ARTIFACT_FILES", ()):
        path = artifacts / name
        if path.exists():
            path.unlink()
            removed.append(name)
    return removed


def select(modules: Dict[str, object],
           only: Optional[List[str]]) -> Dict[str, object]:
    if not only:
        return modules
    picked: Dict[str, object] = {}
    for pat in only:
        want = pat if pat.startswith("bench_") else f"bench_{pat}"
        hits = {n: m for n, m in modules.items()
                if n == want or pat in n}
        if not hits:
            raise SystemExit(
                f"--only {pat!r} matched nothing; available: "
                f"{', '.join(modules)}")
        picked.update(hits)
    return picked


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__.splitlines()[0])
    ap.add_argument("--only", action="append", metavar="NAME",
                    help="run only benches whose module name matches "
                         "(exact bench_NAME or substring); repeatable")
    ap.add_argument("--list", action="store_true",
                    help="list discovered bench modules and exit")
    args = ap.parse_args(argv)
    modules = discover()
    if args.list:
        for n in modules:
            print(n)
        return 0
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in select(modules, args.only).items():
        try:
            for row_name, us, derived in mod.run():
                print(f"{row_name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name},ERROR,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            removed = discard_artifacts(mod)
            if removed:
                print(f"{name}: discarded stale artifacts "
                      f"{', '.join(removed)}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
