"""Paper Table 1 + Fig. 4 — federation utilisation via the monitoring
pipeline.

Replays a production-shaped workload (Table-2 file sizes, Table-1
experiment byte mix, Zipf-popular working sets) through the *functional*
federation.  Every transfer emits user-login/file-open/file-close records;
the collector joins them and the aggregator produces the usage-by-
experiment table (Table 1) and time-bucketed series (Fig. 4).  The ranking
of experiments must reproduce the input mix — closing the loop on §3.2's
monitoring design.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core import (USAGE_BY_EXPERIMENT, build_osg_federation,
                        generate_workload)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ('utilization.json',)


def run(n_requests: int = 300, verbose: bool = False):
    fed = build_osg_federation()
    origin = fed.origins[0]
    sites = [s.name for s in fed.sites]
    trace = generate_workload(sites, n_requests, duration=7 * 86400.0,
                              seed=3, working_set=16)
    published = set()
    for req in trace:
        if req.path not in published:
            origin.put_object(req.path, min(req.size, 64 * 2 ** 20))
            published.add(req.path)
    clients = {}
    for req in trace:
        key = (req.site, req.worker % 4)
        if key not in clients:
            clients[key] = fed.client(req.site, req.worker % 4,
                                      cvmfs=False)
        client = clients[key]
        client.now = req.time
        client.copy(req.path)

    table = fed.aggregator.usage_table()
    series = fed.aggregator.time_series()
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "utilization.json").write_text(json.dumps({
        "usage_table": table, "time_series": series,
        "records": fed.aggregator.records,
        "input_mix": USAGE_BY_EXPERIMENT}, indent=1))
    if verbose:
        print("  rank  experiment                      bytes")
        for i, (exp, b) in enumerate(table[:9]):
            print(f"  {i + 1:>4d}  {exp:<28s} {b / 1e12:8.3f} TB")
        print(f"  monitoring records: {fed.aggregator.records}, "
              f"unjoined: {fed.monitor.unjoined}")
    # Rank agreement with the input mix (top experiment must match).
    input_rank = sorted(USAGE_BY_EXPERIMENT, key=USAGE_BY_EXPERIMENT.get,
                        reverse=True)
    ours_rank = [e for e, _ in table]
    agree = sum(1 for a, b in zip(input_rank[:5], ours_rank[:5]) if a == b)
    return [("utilization.monitoring_pipeline", 0.0,
             f"records={fed.aggregator.records}"),
            ("utilization.top5_rank_agreement", 0.0, f"{agree}/5"),
            ("utilization.time_buckets", 0.0, f"{len(series)}")]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
