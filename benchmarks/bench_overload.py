"""Overload control: p99 latency and goodput with and without throttling.

The control-plane experiment (ISSUE 6): drive one fleet federation at
1x / 2x / 4x its saturating arrival rate, once *unthrottled* (no
control plane: excess load just contends on links, and aggressive
hedging — every straggler spawns a duplicate transfer — burns the spare
capacity that remains) and once *throttled* (admission queues with
bounded depth shed the excess explicitly; breakers and backoff keep
clients from hammering). Load shedding is the point: a cache that
refuses 60% of a 4x storm outright serves the admitted remainder at
near-line rate, while the work-conserving free-for-all drags every
transfer past the hedge deadline and doubles its own traffic.

All runs share one federation shape and one Zipf trace family; load is
the arrival *window* (same bytes, compressed schedule). The saturation
window is where uncontrolled goodput peaks (~9 GB/s on this shape) —
found empirically, pinned here, and cheap to re-derive by sweeping
``--window``.

Artifact ``artifacts/overload.json`` (see docs/BENCHMARKS.md):

* ``baseline`` — the uncontended 1x-rate reference summary;
* ``profile``  — per load factor, ``unthrottled`` / ``throttled``
  ScenarioReport summaries (p99_seconds, goodput, shed_rate, ...);
* ``derived``  — the gated ratios: ``p99_degradation_unthrottled``
  (target >= 2 at 4x), ``goodput_ratio_throttled`` (target >= 0.8 of
  the uncontended baseline), ``throttled_vs_unthrottled_goodput``
  (target >= 1: throttling must *win* at the overload point), and the
  4x ``shed_rate``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core import (ControlPlaneSpec, FederationSpec, ScenarioSpec,
                        WorkloadSpec, run_scenario)

ARTIFACTS = Path(__file__).parent / "artifacts"
ARTIFACT_FILES = ("overload.json",)

# One cache pod pair, 8 workers each; 240 Zipf requests over a 4 s
# window offer ~9 GB/s — the empirical saturation point of this shape.
SATURATION_WINDOW = 4.0
UNCONTENDED_WINDOW = 15.0
HEDGE_AFTER = 0.5
CONTROL = ControlPlaneSpec(max_concurrent=12, queue_depth=8)


def _scenario(name: str, n: int, window: float, seed: int,
              control: ControlPlaneSpec | None) -> ScenarioSpec:
    return ScenarioSpec(
        name=f"overload/{name}", engine="sim",
        federation=FederationSpec.fleet(num_pods=2, hosts_per_pod=8),
        workload=WorkloadSpec(kind="zipf", n_requests=n, working_set=64,
                              duration=window, seed=seed),
        hedge_after=HEDGE_AFTER,
        control=control)


def _run(name: str, n: int, window: float, seed: int,
         control: ControlPlaneSpec | None) -> dict:
    rep = run_scenario(_scenario(name, n, window, seed, control))
    s = rep.summary()
    s["window_seconds"] = window
    return s


def overload_profile(n: int = 240, seed: int = 11,
                     loads: tuple = (1, 2, 4)) -> dict:
    """The full with/without-throttling load ladder + derived ratios."""
    baseline = _run("baseline", n, UNCONTENDED_WINDOW, seed, control=None)
    profile = {}
    for load in loads:
        window = SATURATION_WINDOW / load
        profile[str(load)] = {
            "unthrottled": _run(f"{load}x/unthrottled", n, window, seed,
                                control=None),
            "throttled": _run(f"{load}x/throttled", n, window, seed,
                              control=CONTROL),
        }
    peak = profile[str(max(loads))]
    unthr, thr = peak["unthrottled"], peak["throttled"]
    derived = {
        "overload_factor": max(loads),
        "p99_degradation_unthrottled":
            unthr["p99_seconds"] / max(baseline["p99_seconds"], 1e-9),
        "p99_degradation_throttled":
            thr["p99_seconds"] / max(baseline["p99_seconds"], 1e-9),
        "goodput_ratio_throttled":
            thr["goodput"] / max(baseline["goodput"], 1e-9),
        "goodput_ratio_unthrottled":
            unthr["goodput"] / max(baseline["goodput"], 1e-9),
        "throttled_vs_unthrottled_goodput":
            thr["goodput"] / max(unthr["goodput"], 1e-9),
        "shed_rate": thr["shed_rate"],
    }
    return {"baseline": baseline, "profile": profile, "derived": derived,
            "params": {"n_requests": n, "seed": seed, "loads": list(loads),
                       "saturation_window": SATURATION_WINDOW,
                       "uncontended_window": UNCONTENDED_WINDOW,
                       "hedge_after": HEDGE_AFTER,
                       "max_concurrent": CONTROL.max_concurrent,
                       "queue_depth": CONTROL.queue_depth}}


def run(quick: bool = False, verbose: bool = False):
    t0 = time.perf_counter()
    out = (overload_profile(n=240, loads=(1, 4)) if quick
           else overload_profile())
    wall = time.perf_counter() - t0
    out["wall_seconds"] = wall
    ARTIFACTS.mkdir(exist_ok=True, parents=True)
    (ARTIFACTS / "overload.json").write_text(json.dumps(out, indent=1))
    d = out["derived"]
    peak = out["profile"][str(d["overload_factor"])]
    if verbose:
        b = out["baseline"]
        print(f"  baseline: p99={b['p99_seconds']:.2f}s "
              f"goodput={b['goodput'] / 1e9:.2f} GB/s")
        for load, cell in out["profile"].items():
            u, t = cell["unthrottled"], cell["throttled"]
            print(f"  {load}x: unthrottled p99={u['p99_seconds']:.2f}s "
                  f"gp={u['goodput'] / 1e9:.2f} | throttled "
                  f"p99={t['p99_seconds']:.2f}s gp={t['goodput'] / 1e9:.2f} "
                  f"shed={t['shed_rate']:.2f}")
        print(f"  derived: unthrottled p99 degraded "
              f"{d['p99_degradation_unthrottled']:.1f}x, throttled goodput "
              f"{d['goodput_ratio_throttled']:.2f}x baseline "
              f"({d['throttled_vs_unthrottled_goodput']:.2f}x unthrottled)")
    return [
        ("overload.p99_unthrottled",
         peak["unthrottled"]["p99_seconds"] * 1e6,
         f"degradation={d['p99_degradation_unthrottled']:.1f}x"
         f"@{d['overload_factor']}x"),
        ("overload.p99_throttled",
         peak["throttled"]["p99_seconds"] * 1e6,
         f"degradation={d['p99_degradation_throttled']:.1f}x"
         f"@{d['overload_factor']}x"),
        ("overload.goodput_ratio",
         d["goodput_ratio_throttled"] * 1e6,
         f"vs_unthrottled={d['throttled_vs_unthrottled_goodput']:.2f}x"),
        ("overload.shed_rate",
         d["shed_rate"] * 1e6,
         f"sheds={peak['throttled']['sheds']}"
         f"/{peak['throttled']['requests']}"),
    ]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
