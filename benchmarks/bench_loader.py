"""Data-plane benchmark: federated loader feeding a training job.

Measures the functional (real-bytes) path: step batches assembled from
chunk reads through the pod cache, with prefetch and hedging.  Derived
metrics: accounted federation seconds per step (simulated network time),
wall micro-seconds per step (python+cache machinery cost), and hit rate
after warmup — the number that tells you the origin is out of the loop.
"""
from __future__ import annotations

import time

from repro.core import AnalyticPlane, build_fleet_federation
from repro.data import DatasetSpec, FederatedDataLoader, SyntheticTokens


def run(steps: int = 20, verbose: bool = False):
    fed = build_fleet_federation(num_pods=2, hosts_per_pod=8)
    spec = DatasetSpec("bench", vocab_size=32768,
                       tokens_per_shard=1 << 16, num_shards=16)
    SyntheticTokens(spec).publish(fed.origins[0])
    loader = FederatedDataLoader(AnalyticPlane(fed), spec,
                                 global_batch=8, seq_len=512,
                                 site="pod0", worker=0)
    t0 = time.perf_counter()
    for s in range(steps):
        batch = loader.batch(s)
    wall = (time.perf_counter() - t0) / steps
    st = loader.stats
    if verbose:
        print(f"  {steps} steps, wall {wall * 1e3:.1f} ms/step, "
              f"federation-time {st.fetch_seconds / steps * 1e3:.1f} "
              f"ms/step, hit rate {st.hit_rate:.2f}, "
              f"fetched {st.bytes_fetched / 1e6:.1f} MB")
    return [("loader.step", wall * 1e6,
             f"hit_rate={st.hit_rate:.2f}"),
            ("loader.federation_time_per_step",
             st.fetch_seconds / steps * 1e6,
             f"bytes={st.bytes_fetched}")]


if __name__ == "__main__":
    for name, us, derived in run(verbose=True):
        print(f"{name},{us:.1f},{derived}")
